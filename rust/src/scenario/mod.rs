//! The scenario library: named, parameterized disaster/network regimes.
//!
//! The paper evaluates over exactly one 20-minute scripted trace with one
//! fixed operator intent.  Real deployments face many regimes — wildfire
//! smoke attenuation, urban-canyon flooding, earthquake blackouts,
//! satellite-relay sawtooths — and operators re-task UAVs mid-mission.
//! Each [`Scenario`] composes:
//!
//! * **network dynamics** — a [`TraceConfig`] built from the scenario's
//!   phase script or Markov regime model, plus [`LinkConfig`] knobs
//!   (loss, jitter, fixed extra latency),
//! * **an intent schedule** — timed operator re-taskings
//!   ([`IntentSwitch`]) that move agents between the Context and Insight
//!   streams through the existing controller,
//! * **fleet composition** — size, Context/Insight mix, staggered starts,
//!   cloud workers.
//!
//! Everything is deterministic in `(name, seed, duration)`; the golden
//! trace snapshots in `rust/tests/scenario.rs` pin the generators against
//! silent drift.  Run one with `avery scenario --name <name>`; list them
//! with `avery scenario --list`.

pub mod compile;
pub mod generate;
pub mod manifest;

use anyhow::{bail, Result};

use crate::coordinator::MissionGoal;
use crate::faults::FaultEvent;
use crate::netsim::{BandwidthTrace, LinkConfig, Phase, PhaseKind, TraceConfig};
use crate::streams::IntentSwitch;

/// Fleet composition of a scenario.
#[derive(Clone, Copy, Debug)]
pub struct FleetSpec {
    pub n_uavs: usize,
    /// Every k-th UAV launches on the Context stream (0 = all Insight).
    pub context_every: usize,
    pub stagger_secs: f64,
    pub workers: usize,
    /// Scheduler shards for the megafleet core (`[fleet] shards` in a
    /// manifest); `None` = the legacy single-threaded event loop.  A CLI
    /// `--shards` overrides this.
    pub shards: Option<usize>,
}

/// A named disaster/network regime, fully resolved for one (seed, duration).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub summary: String,
    pub trace: TraceConfig,
    pub link: LinkConfig,
    pub fleet: FleetSpec,
    /// Mission-relative operator re-taskings (offset per UAV by its start).
    pub schedule: Vec<IntentSwitch>,
    pub goal: MissionGoal,
    /// Controller hysteresis margin used by scenario missions.
    pub hysteresis: f64,
    /// Controller minimum-dwell decisions used by scenario missions.
    pub min_dwell: u64,
    /// Deterministic fault schedule, already bound to mission seconds
    /// (empty for every built-in — chaos is opt-in via `[[fault]]`
    /// manifest sections or `--fault-plan`).
    pub faults: Vec<FaultEvent>,
}

/// `(name, one-line summary)` for every registered scenario, in listing
/// order — the static registry index (`build` attaches the same summary to
/// the constructed scenario; pinned by a unit test).
pub const SCENARIOS: [(&str, &str); 5] = [
    (
        "paper-baseline",
        "the paper's 20-min stable/volatile/drop script, single UAV, fixed Insight intent",
    ),
    (
        "wildfire-ridge",
        "Markov smoke-attenuation regimes (stable/volatile/drop), 4 UAVs, \
         triage detour then vehicle re-task",
    ),
    (
        "urban-flood",
        "drop-heavy urban canyon, 6 UAVs, Context→Insight escalation mid-mission \
         (the §4.3 triage workflow)",
    ),
    (
        "earthquake-canyon",
        "two full blackouts between survey legs, lossy link, 2 UAVs — outage \
         recovery stress",
    ),
    (
        "coastal-satellite",
        "satellite-handoff sawtooth + 280 ms propagation, 3 UAVs, throughput-first goal",
    ),
];

/// Registered scenario names, in listing order.
pub const SCENARIO_NAMES: [&str; 5] = [
    SCENARIOS[0].0,
    SCENARIOS[1].0,
    SCENARIOS[2].0,
    SCENARIOS[3].0,
    SCENARIOS[4].0,
];

/// One-line summary of a registered scenario name.
fn summary_of(name: &str) -> &'static str {
    SCENARIOS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, s)| *s)
        .unwrap_or("")
}

/// `(name, one-line summary)` for every registered scenario.
pub fn list() -> Vec<(&'static str, &'static str)> {
    SCENARIOS.to_vec()
}

/// Build a registered scenario for a seed and mission duration (seconds).
pub fn build(name: &str, seed: u64, duration_secs: f64) -> Result<Scenario> {
    let d = duration_secs;
    match name {
        // The paper's §5.3 reproduction: one 20-minute script, one standing
        // Insight intent, a dedicated-feeling uplink (N=1).
        "paper-baseline" => Ok(Scenario {
            name: "paper-baseline".to_string(),
            summary: summary_of("paper-baseline").to_string(),
            trace: TraceConfig::paper_20min(seed).scaled_to(d),
            link: LinkConfig { seed, ..LinkConfig::default() },
            fleet: FleetSpec { n_uavs: 1, context_every: 0, stagger_secs: 0.0, workers: 1, shards: None },
            schedule: Vec::new(),
            goal: MissionGoal::PrioritizeAccuracy,
            hysteresis: 0.0,
            min_dwell: 0,
            faults: Vec::new(),
        }),

        // Smoke plumes drifting across the ridge line: Markov-modulated
        // switching between calm, turbulent and attenuated regimes, with a
        // mid-mission triage detour and a late re-tasking onto vehicles.
        "wildfire-ridge" => Ok(Scenario {
            name: "wildfire-ridge".to_string(),
            summary: summary_of("wildfire-ridge").to_string(),
            trace: TraceConfig::markov_modulated(
                seed,
                d,
                8.0,
                20.0,
                (d / 12.0).max(20.0),
                &[PhaseKind::Stable, PhaseKind::Volatile, PhaseKind::Drop],
            ),
            link: LinkConfig { loss_prob: 0.01, jitter_std: 0.04, seed, ..LinkConfig::default() },
            fleet: FleetSpec { n_uavs: 4, context_every: 4, stagger_secs: 5.0, workers: 2, shards: None },
            schedule: vec![
                IntentSwitch::new(0.55 * d, "give me a quick status of this scene"),
                IntentSwitch::new(0.75 * d, "mark the submerged vehicles"),
            ],
            goal: MissionGoal::PrioritizeAccuracy,
            hysteresis: 0.10,
            min_dwell: 2,
            faults: Vec::new(),
        }),

        // The §4.3 triage-escalation story on a flooded urban canyon: a
        // paper-like drop-heavy script, lossier link, and the operator
        // walking the fleet from awareness into grounded segmentation.
        "urban-flood" => Ok(Scenario {
            name: "urban-flood".to_string(),
            summary: summary_of("urban-flood").to_string(),
            trace: TraceConfig {
                phases: vec![
                    Phase { kind: PhaseKind::Stable, secs: 0.15 * d, level_mbps: 16.0 },
                    Phase { kind: PhaseKind::Volatile, secs: 0.20 * d, level_mbps: 13.0 },
                    Phase { kind: PhaseKind::Drop, secs: 0.15 * d, level_mbps: 8.5 },
                    Phase { kind: PhaseKind::Stable, secs: 0.10 * d, level_mbps: 15.0 },
                    Phase { kind: PhaseKind::Drop, secs: 0.20 * d, level_mbps: 9.0 },
                    Phase { kind: PhaseKind::Volatile, secs: 0.10 * d, level_mbps: 12.0 },
                    Phase { kind: PhaseKind::Stable, secs: 0.10 * d, level_mbps: 17.0 },
                ],
                min_mbps: 8.0,
                max_mbps: 20.0,
                dt: 1.0,
                seed,
            },
            link: LinkConfig { loss_prob: 0.02, seed, ..LinkConfig::default() },
            fleet: FleetSpec { n_uavs: 6, context_every: 3, stagger_secs: 8.0, workers: 2, shards: None },
            schedule: vec![
                IntentSwitch::new(0.40 * d, "are there any living beings on the rooftops"),
                IntentSwitch::new(0.60 * d, "highlight the stranded people"),
            ],
            goal: MissionGoal::PrioritizeAccuracy,
            hysteresis: 0.10,
            min_dwell: 2,
            faults: Vec::new(),
        }),

        // Aftershock terrain: repeated full blackouts between survey legs —
        // the outage-recovery stress case (infeasible epochs, estimator
        // collapse and recovery).
        "earthquake-canyon" => Ok(Scenario {
            name: "earthquake-canyon".to_string(),
            summary: summary_of("earthquake-canyon").to_string(),
            trace: TraceConfig {
                phases: vec![
                    Phase { kind: PhaseKind::Stable, secs: 0.20 * d, level_mbps: 15.0 },
                    Phase { kind: PhaseKind::Outage, secs: 0.08 * d, level_mbps: 0.05 },
                    Phase { kind: PhaseKind::Volatile, secs: 0.22 * d, level_mbps: 12.0 },
                    Phase { kind: PhaseKind::Outage, secs: 0.10 * d, level_mbps: 0.05 },
                    Phase { kind: PhaseKind::Drop, secs: 0.20 * d, level_mbps: 8.5 },
                    Phase { kind: PhaseKind::Stable, secs: 0.20 * d, level_mbps: 16.0 },
                ],
                min_mbps: 8.0,
                max_mbps: 20.0,
                dt: 1.0,
                seed,
            },
            link: LinkConfig { loss_prob: 0.03, jitter_std: 0.05, seed, ..LinkConfig::default() },
            fleet: FleetSpec { n_uavs: 2, context_every: 0, stagger_secs: 10.0, workers: 1, shards: None },
            schedule: Vec::new(),
            goal: MissionGoal::PrioritizeAccuracy,
            hysteresis: 0.10,
            min_dwell: 2,
            faults: Vec::new(),
        }),

        // Coastal relay through a LEO constellation: per-pass sawtooth
        // ramps with handoff snap-backs and a fixed propagation latency;
        // throughput-first tasking with a late vehicle re-task.
        "coastal-satellite" => Ok(Scenario {
            name: "coastal-satellite".to_string(),
            summary: summary_of("coastal-satellite").to_string(),
            trace: TraceConfig {
                phases: vec![
                    Phase { kind: PhaseKind::Sawtooth, secs: 0.30 * d, level_mbps: 9.0 },
                    Phase { kind: PhaseKind::Stable, secs: 0.10 * d, level_mbps: 18.0 },
                    Phase { kind: PhaseKind::Sawtooth, secs: 0.30 * d, level_mbps: 8.5 },
                    Phase { kind: PhaseKind::Volatile, secs: 0.10 * d, level_mbps: 12.0 },
                    Phase { kind: PhaseKind::Sawtooth, secs: 0.20 * d, level_mbps: 10.0 },
                ],
                min_mbps: 8.0,
                max_mbps: 20.0,
                dt: 1.0,
                seed,
            },
            link: LinkConfig {
                loss_prob: 0.01,
                extra_latency_s: 0.28,
                seed,
                ..LinkConfig::default()
            },
            fleet: FleetSpec { n_uavs: 3, context_every: 3, stagger_secs: 6.0, workers: 2, shards: None },
            schedule: vec![IntentSwitch::new(0.50 * d, "mark the submerged vehicles")],
            goal: MissionGoal::PrioritizeThroughput,
            hysteresis: 0.10,
            min_dwell: 2,
            faults: Vec::new(),
        }),

        other => bail!(
            "unknown scenario `{other}` — run `avery scenario --list` \
             (registered: {})",
            SCENARIO_NAMES.join(", ")
        ),
    }
}

/// Summary statistics of a generated scenario trace — the quantities the
/// golden-trace regression snapshots pin.
#[derive(Clone, Copy, Debug)]
pub struct TraceSummary {
    pub mean_mbps: f64,
    pub min_mbps: f64,
    pub max_mbps: f64,
    /// Seconds spent below half the configured floor (outage dwell).
    pub outage_secs: f64,
    /// Number of scripted/Markov regimes (phase count).
    pub regimes: usize,
}

/// Summarize a generated trace against its config.
pub fn summarize_trace(cfg: &TraceConfig, trace: &BandwidthTrace) -> TraceSummary {
    let s = &trace.samples_mbps;
    let n = s.len().max(1) as f64;
    let outage_thresh = 0.5 * cfg.min_mbps;
    TraceSummary {
        mean_mbps: s.iter().sum::<f64>() / n,
        min_mbps: s.iter().cloned().fold(f64::INFINITY, f64::min),
        max_mbps: s.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        outage_secs: s.iter().filter(|&&b| b < outage_thresh).count() as f64 * trace.dt,
        regimes: cfg.phases.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_name() {
        for name in SCENARIO_NAMES {
            let sc = build(name, 7, 600.0).unwrap();
            assert_eq!(sc.name, name);
            assert!(!sc.summary.is_empty(), "{name} listed without a summary");
            assert!((sc.trace.total_secs() - 600.0).abs() < 1e-6, "{name}");
            assert!(sc.fleet.n_uavs >= 1);
        }
        assert!(build("nope", 7, 600.0).is_err());
        assert_eq!(list().len(), SCENARIO_NAMES.len());
        // The static index and the buildable set stay aligned.
        for (n, s) in SCENARIOS {
            assert!(SCENARIO_NAMES.contains(&n));
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn library_covers_required_diversity() {
        // At least one registered scenario with a full outage phase...
        assert!(SCENARIO_NAMES.iter().any(|n| {
            build(n, 7, 600.0)
                .unwrap()
                .trace
                .phases
                .iter()
                .any(|p| p.kind == PhaseKind::Outage)
        }));
        // ...at least one with a mid-mission intent switch...
        assert!(SCENARIO_NAMES
            .iter()
            .any(|n| !build(n, 7, 600.0).unwrap().schedule.is_empty()));
        // ...and at least one satellite sawtooth with extra latency.
        assert!(SCENARIO_NAMES.iter().any(|n| {
            let sc = build(n, 7, 600.0).unwrap();
            sc.link.extra_latency_s > 0.0
                && sc.trace.phases.iter().any(|p| p.kind == PhaseKind::Sawtooth)
        }));
    }

    #[test]
    fn schedules_fit_inside_the_mission() {
        for name in SCENARIO_NAMES {
            let sc = build(name, 7, 600.0).unwrap();
            for sw in &sc.schedule {
                assert!(sw.t > 0.0 && sw.t < 600.0, "{name} switch at {}", sw.t);
            }
        }
    }

    #[test]
    fn trace_summary_counts_outage() {
        let sc = build("earthquake-canyon", 7, 600.0).unwrap();
        let tr = BandwidthTrace::generate(&sc.trace);
        let sum = summarize_trace(&sc.trace, &tr);
        // 18 % of the mission is scripted blackout.
        assert!(sum.outage_secs > 0.15 * 600.0 && sum.outage_secs < 0.21 * 600.0);
        assert!(sum.min_mbps < 1.0);
        assert_eq!(sum.regimes, 6);
    }
}
