//! Scenario manifest parser: the TOML subset the scenario compiler reads.
//!
//! Hand-rolled like every other format in this repo (`report.rs` emits
//! JSON by hand, `config.rs` parses `key = value`) — the offline crate set
//! has no serde/toml.  The subset is exactly what scenario manifests need:
//!
//! ```text
//! # comment (quote-aware: `#` inside strings is literal)
//! key = "string"            # top-level scalars
//! key = 3.5                 # numbers (always f64)
//! key = true                # booleans
//! key = ["a", "b"]          # flat lists of scalars
//! [section]                 # named table ([trace], [link], [fleet])
//! key = value
//! [[entry]]                 # array-of-tables ([[phase]], [[intent]], [[fault]])
//! key = value
//! ```
//!
//! No nesting, no inline tables, no multi-line values, no commas inside
//! quoted list elements.  The parser only builds the [`Doc`] tree and
//! reports syntax errors with line numbers; all semantic checking (known
//! keys, required keys, value ranges) is the compile pipeline's job
//! (`scenario::compile`), so every diagnostic names the offending key.

use std::fmt;

/// A parsed manifest value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    /// Human name of the value's type, for diagnostics.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Num(_) => "number",
            Value::Bool(_) => "boolean",
            Value::List(_) => "list",
        }
    }
}

/// One flat key → value table, preserving insertion order (manifests are
/// small; linear scans keep the structure dependency-free).
#[derive(Clone, Debug, Default)]
pub struct Table {
    entries: Vec<(String, Value)>,
}

impl Table {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Insert or replace — later assignments (and include overrides) win.
    pub fn set(&mut self, key: &str, value: Value) {
        match self.entries.iter_mut().find(|(k, _)| k == key) {
            Some((_, v)) => *v = value,
            None => self.entries.push((key.to_string(), value)),
        }
    }

    pub fn remove(&mut self, key: &str) -> Option<Value> {
        let i = self.entries.iter().position(|(k, _)| k == key)?;
        Some(self.entries.remove(i).1)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(k, _)| k.as_str())
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// A parsed manifest: top-level keys, named tables, arrays of tables.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub root: Table,
    pub tables: Vec<(String, Table)>,
    pub arrays: Vec<(String, Vec<Table>)>,
}

impl Doc {
    /// The named `[section]` table, if present.
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Every `[[name]]` entry, in file order (empty slice when absent).
    pub fn array(&self, name: &str) -> &[Table] {
        self.arrays
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, ts)| ts.as_slice())
            .unwrap_or(&[])
    }

    /// Parse manifest text into a [`Doc`]; syntax errors carry the line.
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        enum Cur {
            Root,
            Table(usize),
            Array(usize),
        }
        let mut doc = Doc::default();
        let mut cur = Cur::Root;
        for (i, raw) in text.lines().enumerate() {
            let line = i + 1;
            let stripped = strip_comment(raw);
            let s = stripped.trim();
            if s.is_empty() {
                continue;
            }
            if let Some(inner) = s.strip_prefix("[[") {
                let Some(name) = inner.strip_suffix("]]").map(str::trim) else {
                    return Err(ParseError::new(line, "unterminated [[header]]"));
                };
                check_ident(name, line)?;
                let ai = match doc.arrays.iter().position(|(n, _)| n == name) {
                    Some(ai) => ai,
                    None => {
                        doc.arrays.push((name.to_string(), Vec::new()));
                        doc.arrays.len() - 1
                    }
                };
                doc.arrays[ai].1.push(Table::new());
                cur = Cur::Array(ai);
            } else if let Some(inner) = s.strip_prefix('[') {
                let Some(name) = inner.strip_suffix(']').map(str::trim) else {
                    return Err(ParseError::new(line, "unterminated [header]"));
                };
                check_ident(name, line)?;
                let ti = match doc.tables.iter().position(|(n, _)| n == name) {
                    Some(ti) => ti,
                    None => {
                        doc.tables.push((name.to_string(), Table::new()));
                        doc.tables.len() - 1
                    }
                };
                cur = Cur::Table(ti);
            } else {
                let Some((k, v)) = s.split_once('=') else {
                    return Err(ParseError::new(line, "expected `key = value` or a [header]"));
                };
                let key = k.trim();
                check_ident(key, line)?;
                let value = parse_value(v.trim(), line)?;
                let target = match cur {
                    Cur::Root => &mut doc.root,
                    Cur::Table(ti) => &mut doc.tables[ti].1,
                    Cur::Array(ai) => doc.arrays[ai]
                        .1
                        .last_mut()
                        .expect("array header always pushes a table"),
                };
                target.set(key, value);
            }
        }
        Ok(doc)
    }
}

/// A manifest syntax error (line-numbered; the compile pipeline wraps it
/// with the file path).
#[derive(Clone, Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl ParseError {
    fn new(line: usize, msg: impl Into<String>) -> Self {
        Self { line, msg: msg.into() }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

/// Cut a trailing `# comment`, treating `#` inside a quoted string as
/// literal content.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn check_ident(name: &str, line: usize) -> Result<(), ParseError> {
    if name.is_empty() {
        return Err(ParseError::new(line, "empty identifier"));
    }
    if let Some(c) =
        name.chars().find(|c| !(c.is_ascii_alphanumeric() || *c == '-' || *c == '_'))
    {
        return Err(ParseError::new(line, format!("bad character `{c}` in `{name}`")));
    }
    Ok(())
}

fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(content) = inner.strip_suffix('"') else {
            return Err(ParseError::new(line, format!("unterminated string `{s}`")));
        };
        if content.contains('"') {
            return Err(ParseError::new(line, "embedded `\"` in string (no escapes)"));
        }
        return Ok(Value::Str(content.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    match s.parse::<f64>() {
        Ok(n) if n.is_finite() => Ok(Value::Num(n)),
        _ => Err(ParseError::new(line, format!("unparseable value `{s}`"))),
    }
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return Err(ParseError::new(line, "missing value after `=`"));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            return Err(ParseError::new(line, "unterminated list"));
        };
        if body.trim().is_empty() {
            return Ok(Value::List(Vec::new()));
        }
        let mut items = Vec::new();
        for item in body.split(',') {
            items.push(parse_scalar(item.trim(), line)?);
        }
        return Ok(Value::List(items));
    }
    parse_scalar(s, line)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_supported_shape() {
        let doc = Doc::parse(
            "name = \"demo\" # trailing comment\n\
             frac = 0.25\n\
             flag = true\n\
             note = \"has # inside\"\n\
             [trace]\n\
             min_mbps = 8\n\
             markov_kinds = [\"stable\", \"drop\"]\n\
             [[phase]]\n\
             kind = \"stable\"\n\
             [[phase]]\n\
             kind = \"drop\"\n",
        )
        .unwrap();
        assert_eq!(doc.root.get("name"), Some(&Value::Str("demo".into())));
        assert_eq!(doc.root.get("frac"), Some(&Value::Num(0.25)));
        assert_eq!(doc.root.get("flag"), Some(&Value::Bool(true)));
        assert_eq!(doc.root.get("note"), Some(&Value::Str("has # inside".into())));
        let tr = doc.table("trace").unwrap();
        assert_eq!(tr.get("min_mbps"), Some(&Value::Num(8.0)));
        assert_eq!(
            tr.get("markov_kinds"),
            Some(&Value::List(vec![Value::Str("stable".into()), Value::Str("drop".into())]))
        );
        assert_eq!(doc.array("phase").len(), 2);
        assert!(doc.array("intent").is_empty());
    }

    #[test]
    fn later_assignments_replace_earlier_ones() {
        let doc = Doc::parse("a = 1\na = 2\n").unwrap();
        assert_eq!(doc.root.get("a"), Some(&Value::Num(2.0)));
    }

    #[test]
    fn syntax_errors_carry_line_numbers() {
        for (text, line) in [
            ("ok = 1\nnot a pair\n", 2),
            ("[unclosed\n", 1),
            ("x = \"unterminated\n", 1),
            ("x = [1, 2\n", 1),
            ("ok = 1\nx = @nan@\n", 2),
            ("bad key! = 1\n", 1),
            ("x =\n", 1),
        ] {
            let err = Doc::parse(text).unwrap_err();
            assert_eq!(err.line, line, "{text:?} -> {err}");
        }
    }
}
