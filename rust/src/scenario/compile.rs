//! Scenario compile pipeline: manifest text → validated [`CompiledScenario`]
//! → [`Scenario`], in staged passes (DESIGN.md "Scenario compiler"):
//!
//! 1. **parse** — `manifest::Doc::parse`, syntax errors with line numbers;
//! 2. **include resolution** — a manifest may `include = "base.toml"`
//!    (file-relative); the including file's keys override the base's,
//!    tables merge key-wise, arrays replace whole.  Cycles and depth > 8
//!    are [`CompileError::IncludeCycle`];
//! 3. **default resolution + key audit** — unknown keys/sections/arrays
//!    are rejected ([`CompileError::UnknownKey`]), missing optional keys
//!    take the documented defaults, missing required ones are
//!    [`CompileError::MissingKey`];
//! 4. **symbolic validation** — phase windows (positive durations,
//!    fractions summing to 1, no frac/secs mixing), rate bounds (clamp
//!    band, per-phase anchor levels, link loss/jitter/latency), intent
//!    schedule ordering and fleet shape — all *before* any simulation
//!    runs, each diagnostic naming the offending key path
//!    (`phase[2].level_mbps`, `trace.min_mbps`, ...);
//! 5. **lowering** — [`CompiledScenario::instantiate`] binds `(seed,
//!    duration)` and produces the same [`Scenario`] value the hand-coded
//!    `scenario::build` arms produce — bit-for-bit, so the checked-in
//!    manifests under `scenarios/` reproduce the built-in fleet CSVs
//!    byte-identically (pinned by `rust/tests/matrix.rs` and CI).
//!
//! Phase durations come in three modes, mirroring the built-ins exactly:
//! fractional (`frac = 0.15` → `0.15 * duration`), absolute (`secs = 180`
//! then `scaled_to(duration)` — the paper-baseline path), and Markov
//! (`markov_dwell_div`/`markov_dwell_min_s` express the built-ins'
//! `(duration / div).max(min)` mean dwell, because `d / 12.0` and
//! `d * (1.0 / 12.0)` are *not* the same IEEE value).

use std::fmt;
use std::path::{Path, PathBuf};

use crate::coordinator::MissionGoal;
use crate::faults::{bind_specs, FaultKind, FaultSpec};
use crate::netsim::{LinkConfig, Phase, PhaseKind, TraceConfig, OUTAGE_FLOOR_MBPS};
use crate::streams::IntentSwitch;

use super::manifest::{Doc, Table, Value};
use super::{FleetSpec, Scenario};

/// Maximum include-chain depth before the resolver assumes a cycle.
const MAX_INCLUDE_DEPTH: usize = 8;

/// A structured compile diagnostic.  Every semantic variant names the
/// offending key path (`trace.min_mbps`, `phase[2].frac`, ...), so a
/// failing manifest is fixable without reading the compiler.
#[derive(Clone, Debug)]
pub enum CompileError {
    /// Manifest syntax error (pass 1).
    Parse { path: String, line: usize, msg: String },
    /// Manifest file unreadable (or `include` used where no file system
    /// context exists, e.g. `compile_str`).
    Io { path: String, msg: String },
    /// `include` chain revisits a file or exceeds the depth bound.
    IncludeCycle { path: String },
    /// A required key is absent.
    MissingKey { key: String },
    /// A key/section the schema does not define.
    UnknownKey { key: String },
    /// Wrong type, malformed enum value, or out-of-domain scalar.
    BadValue { key: String, msg: String },
    /// Phase-script structure: non-positive windows, frac/secs mixing,
    /// fractions not summing to 1, phases alongside Markov keys.
    PhaseWindow { key: String, msg: String },
    /// Bandwidth/link rate outside its legal band.
    RateBound { key: String, msg: String },
    /// Intent schedule out of order or outside the mission window.
    ScheduleOrder { key: String, msg: String },
    /// Fleet composition out of range.
    FleetSpec { key: String, msg: String },
    /// Fault schedule: bad kind, out-of-domain window/rate, disorder, or
    /// overlapping same-cell crash windows.
    FaultSchedule { key: String, msg: String },
}

impl CompileError {
    /// The offending key path, for semantic variants (`None` for
    /// file-level errors, which carry a path instead).
    pub fn key_path(&self) -> Option<&str> {
        match self {
            CompileError::Parse { .. }
            | CompileError::Io { .. }
            | CompileError::IncludeCycle { .. } => None,
            CompileError::MissingKey { key }
            | CompileError::UnknownKey { key }
            | CompileError::BadValue { key, .. }
            | CompileError::PhaseWindow { key, .. }
            | CompileError::RateBound { key, .. }
            | CompileError::ScheduleOrder { key, .. }
            | CompileError::FleetSpec { key, .. }
            | CompileError::FaultSchedule { key, .. } => Some(key),
        }
    }
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompileError::Parse { path, line, msg } => {
                write!(f, "{path}:{line}: {msg}")
            }
            CompileError::Io { path, msg } => write!(f, "{path}: {msg}"),
            CompileError::IncludeCycle { path } => {
                write!(f, "include cycle (or depth > {MAX_INCLUDE_DEPTH}) through {path}")
            }
            CompileError::MissingKey { key } => write!(f, "missing required key `{key}`"),
            CompileError::UnknownKey { key } => write!(f, "unknown key `{key}`"),
            CompileError::BadValue { key, msg } => write!(f, "bad value for `{key}`: {msg}"),
            CompileError::PhaseWindow { key, msg } => {
                write!(f, "phase window at `{key}`: {msg}")
            }
            CompileError::RateBound { key, msg } => write!(f, "rate bound at `{key}`: {msg}"),
            CompileError::ScheduleOrder { key, msg } => {
                write!(f, "intent schedule at `{key}`: {msg}")
            }
            CompileError::FleetSpec { key, msg } => write!(f, "fleet spec at `{key}`: {msg}"),
            CompileError::FaultSchedule { key, msg } => {
                write!(f, "fault schedule at `{key}`: {msg}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// The phase script a manifest lowers to, before `(seed, duration)` bind.
#[derive(Clone, Debug)]
pub enum TraceSpec {
    /// Scripted phases.  `fractional`: durations are mission fractions
    /// (`frac * duration`); otherwise absolute seconds rescaled through
    /// `TraceConfig::scaled_to` exactly like the paper-baseline arm.
    Phases { phases: Vec<(PhaseKind, f64, f64)>, fractional: bool },
    /// Markov regime switching; mean dwell = `(duration / dwell_div)
    /// .max(dwell_min_secs)`.
    Markov { kinds: Vec<PhaseKind>, dwell_div: f64, dwell_min_secs: f64 },
}

/// A validated, seed/duration-free scenario template — the compiler's
/// output, instantiable any number of times.
#[derive(Clone, Debug)]
pub struct CompiledScenario {
    pub name: String,
    pub summary: String,
    pub goal: MissionGoal,
    pub hysteresis: f64,
    pub min_dwell: u64,
    pub min_mbps: f64,
    pub max_mbps: f64,
    pub dt: f64,
    pub trace: TraceSpec,
    pub loss_prob: f64,
    pub jitter_std: f64,
    pub extra_latency_s: f64,
    pub fleet: FleetSpec,
    /// `(mission fraction, prompt)`, strictly increasing in fraction.
    pub schedule: Vec<(f64, String)>,
    /// Fraction-based fault schedule, bound to mission seconds at
    /// instantiation (empty unless the manifest declares `[[fault]]`).
    pub faults: Vec<FaultSpec>,
}

impl CompiledScenario {
    /// Bind a seed and mission duration, producing the same [`Scenario`]
    /// value the hand-coded `build` arms construct.
    pub fn instantiate(&self, seed: u64, duration_secs: f64) -> Scenario {
        let d = duration_secs;
        let trace = match &self.trace {
            TraceSpec::Markov { kinds, dwell_div, dwell_min_secs } => {
                TraceConfig::markov_modulated(
                    seed,
                    d,
                    self.min_mbps,
                    self.max_mbps,
                    (d / dwell_div).max(*dwell_min_secs),
                    kinds,
                )
            }
            TraceSpec::Phases { phases, fractional } => {
                let cfg = TraceConfig {
                    phases: phases
                        .iter()
                        .map(|&(kind, dur, level_mbps)| Phase {
                            kind,
                            secs: if *fractional { dur * d } else { dur },
                            level_mbps,
                        })
                        .collect(),
                    min_mbps: self.min_mbps,
                    max_mbps: self.max_mbps,
                    dt: self.dt,
                    seed,
                };
                if *fractional {
                    cfg
                } else {
                    cfg.scaled_to(d)
                }
            }
        };
        Scenario {
            name: self.name.clone(),
            summary: self.summary.clone(),
            trace,
            link: LinkConfig {
                loss_prob: self.loss_prob,
                jitter_std: self.jitter_std,
                extra_latency_s: self.extra_latency_s,
                seed,
            },
            fleet: self.fleet,
            schedule: self
                .schedule
                .iter()
                .map(|(frac, prompt)| IntentSwitch::new(frac * d, prompt))
                .collect(),
            goal: self.goal,
            hysteresis: self.hysteresis,
            min_dwell: self.min_dwell,
            faults: bind_specs(&self.faults, d),
        }
    }
}

/// Compile manifest text (no file system: `include` is rejected here).
pub fn compile_str(text: &str) -> Result<CompiledScenario, CompileError> {
    let doc = Doc::parse(text).map_err(|e| CompileError::Parse {
        path: "<inline>".to_string(),
        line: e.line,
        msg: e.msg,
    })?;
    if doc.root.get("include").is_some() {
        return Err(CompileError::Io {
            path: "<inline>".to_string(),
            msg: "`include` is only resolved when compiling from a file".to_string(),
        });
    }
    lower(&doc)
}

/// Compile a manifest file, resolving its `include` chain.
pub fn compile_file(path: &Path) -> Result<CompiledScenario, CompileError> {
    let doc = load_with_includes(path, &mut Vec::new())?;
    lower(&doc)
}

fn load_with_includes(path: &Path, stack: &mut Vec<PathBuf>) -> Result<Doc, CompileError> {
    let display = path.display().to_string();
    let text = std::fs::read_to_string(path)
        .map_err(|e| CompileError::Io { path: display.clone(), msg: e.to_string() })?;
    let canon = std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf());
    if stack.contains(&canon) || stack.len() >= MAX_INCLUDE_DEPTH {
        return Err(CompileError::IncludeCycle { path: display });
    }
    let mut doc = Doc::parse(&text).map_err(|e| CompileError::Parse {
        path: display,
        line: e.line,
        msg: e.msg,
    })?;
    let Some(inc) = doc.root.remove("include") else { return Ok(doc) };
    let Value::Str(rel) = inc else {
        return Err(CompileError::BadValue {
            key: "include".to_string(),
            msg: format!("expected a string path, got {}", inc.type_name()),
        });
    };
    let base_path = path.parent().unwrap_or_else(|| Path::new(".")).join(rel);
    stack.push(canon);
    let base = load_with_includes(&base_path, stack)?;
    stack.pop();
    Ok(merge(base, doc))
}

/// Overlay `over` on `base`: root keys override, same-named tables merge
/// key-wise, arrays replace whole (a partial phase override would be a
/// silently different script).
fn merge(mut base: Doc, over: Doc) -> Doc {
    for (name, table) in over.tables {
        match base.tables.iter_mut().find(|(n, _)| *n == name) {
            Some((_, bt)) => {
                for key in table.keys().map(String::from).collect::<Vec<_>>() {
                    bt.set(&key, table.get(&key).cloned().expect("key just listed"));
                }
            }
            None => base.tables.push((name, table)),
        }
    }
    for (name, tables) in over.arrays {
        match base.arrays.iter_mut().find(|(n, _)| *n == name) {
            Some((_, bt)) => *bt = tables,
            None => base.arrays.push((name, tables)),
        }
    }
    for key in over.root.keys().map(String::from).collect::<Vec<_>>() {
        base.root.set(&key, over.root.get(&key).cloned().expect("key just listed"));
    }
    base
}

// ---------------------------------------------------------------------------
// Typed accessors (every mismatch names the key path)
// ---------------------------------------------------------------------------

fn want_num(v: &Value, key: &str) -> Result<f64, CompileError> {
    match v {
        Value::Num(n) => Ok(*n),
        other => Err(CompileError::BadValue {
            key: key.to_string(),
            msg: format!("expected a number, got {}", other.type_name()),
        }),
    }
}

fn want_str<'v>(v: &'v Value, key: &str) -> Result<&'v str, CompileError> {
    match v {
        Value::Str(s) => Ok(s),
        other => Err(CompileError::BadValue {
            key: key.to_string(),
            msg: format!("expected a string, got {}", other.type_name()),
        }),
    }
}

fn want_usize(v: &Value, key: &str) -> Result<usize, CompileError> {
    let n = want_num(v, key)?;
    if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
        return Err(CompileError::BadValue {
            key: key.to_string(),
            msg: format!("expected a non-negative integer, got {n}"),
        });
    }
    Ok(n as usize)
}

fn opt_num(t: &Table, section: &str, key: &str, default: f64) -> Result<f64, CompileError> {
    match t.get(key) {
        None => Ok(default),
        Some(v) => want_num(v, &format!("{section}.{key}")),
    }
}

fn opt_usize(
    t: &Table,
    section: &str,
    key: &str,
    default: usize,
) -> Result<usize, CompileError> {
    match t.get(key) {
        None => Ok(default),
        Some(v) => want_usize(v, &format!("{section}.{key}")),
    }
}

fn audit_keys(t: &Table, section: &str, known: &[&str]) -> Result<(), CompileError> {
    for k in t.keys() {
        if !known.contains(&k) {
            let key = if section.is_empty() {
                k.to_string()
            } else {
                format!("{section}.{k}")
            };
            return Err(CompileError::UnknownKey { key });
        }
    }
    Ok(())
}

fn parse_kind(s: &str, key: &str) -> Result<PhaseKind, CompileError> {
    match s {
        "stable" => Ok(PhaseKind::Stable),
        "volatile" => Ok(PhaseKind::Volatile),
        "drop" => Ok(PhaseKind::Drop),
        "outage" => Ok(PhaseKind::Outage),
        "sawtooth" => Ok(PhaseKind::Sawtooth),
        other => Err(CompileError::BadValue {
            key: key.to_string(),
            msg: format!(
                "unknown phase kind `{other}` (stable|volatile|drop|outage|sawtooth)"
            ),
        }),
    }
}

// ---------------------------------------------------------------------------
// Passes 3–5: key audit, defaults, symbolic validation, lowering
// ---------------------------------------------------------------------------

fn lower(doc: &Doc) -> Result<CompiledScenario, CompileError> {
    // Sections and arrays the schema defines; anything else is a typo.
    for (name, _) in &doc.tables {
        if !["trace", "link", "fleet"].contains(&name.as_str()) {
            return Err(CompileError::UnknownKey { key: format!("[{name}]") });
        }
    }
    for (name, _) in &doc.arrays {
        if !["phase", "intent", "fault"].contains(&name.as_str()) {
            return Err(CompileError::UnknownKey { key: format!("[[{name}]]") });
        }
    }
    audit_keys(
        &doc.root,
        "",
        &["schema", "name", "summary", "goal", "hysteresis", "min_dwell", "include"],
    )?;

    if let Some(v) = doc.root.get("schema") {
        let n = want_num(v, "schema")?;
        if n != 1.0 {
            return Err(CompileError::BadValue {
                key: "schema".to_string(),
                msg: format!("unsupported schema version {n} (expected 1)"),
            });
        }
    }

    let name = match doc.root.get("name") {
        None => return Err(CompileError::MissingKey { key: "name".to_string() }),
        Some(v) => want_str(v, "name")?.to_string(),
    };
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
    {
        return Err(CompileError::BadValue {
            key: "name".to_string(),
            msg: format!("`{name}` is not a valid scenario name ([A-Za-z0-9_-]+)"),
        });
    }
    let summary = match doc.root.get("summary") {
        None => String::new(),
        Some(v) => want_str(v, "summary")?.to_string(),
    };
    let goal = match doc.root.get("goal") {
        None => MissionGoal::PrioritizeAccuracy,
        Some(v) => match want_str(v, "goal")? {
            "accuracy" => MissionGoal::PrioritizeAccuracy,
            "throughput" => MissionGoal::PrioritizeThroughput,
            other => {
                return Err(CompileError::BadValue {
                    key: "goal".to_string(),
                    msg: format!("expected accuracy|throughput, got `{other}`"),
                })
            }
        },
    };
    let hysteresis = opt_num(&doc.root, "", "hysteresis", 0.10).map_err(|e| match e {
        CompileError::BadValue { msg, .. } => {
            CompileError::BadValue { key: "hysteresis".to_string(), msg }
        }
        other => other,
    })?;
    if !(0.0..=0.5).contains(&hysteresis) {
        return Err(CompileError::BadValue {
            key: "hysteresis".to_string(),
            msg: format!("{hysteresis} outside [0, 0.5]"),
        });
    }
    let min_dwell = match doc.root.get("min_dwell") {
        None => 2,
        Some(v) => want_usize(v, "min_dwell")? as u64,
    };

    // ---- [trace]: clamp band, resolution, Markov keys -------------------
    let empty = Table::new();
    let trace_t = doc.table("trace").unwrap_or(&empty);
    audit_keys(
        trace_t,
        "trace",
        &["min_mbps", "max_mbps", "dt", "markov_kinds", "markov_dwell_div",
          "markov_dwell_min_s"],
    )?;
    let min_mbps = opt_num(trace_t, "trace", "min_mbps", 8.0)?;
    let max_mbps = opt_num(trace_t, "trace", "max_mbps", 20.0)?;
    let dt = opt_num(trace_t, "trace", "dt", 1.0)?;
    if min_mbps <= 0.0 {
        return Err(CompileError::RateBound {
            key: "trace.min_mbps".to_string(),
            msg: format!("clamp floor {min_mbps} must be > 0"),
        });
    }
    if max_mbps <= min_mbps {
        return Err(CompileError::RateBound {
            key: "trace.max_mbps".to_string(),
            msg: format!("clamp ceiling {max_mbps} must exceed the floor {min_mbps}"),
        });
    }
    if dt <= 0.0 {
        return Err(CompileError::RateBound {
            key: "trace.dt".to_string(),
            msg: format!("sampling resolution {dt} must be > 0"),
        });
    }

    // ---- phase script xor Markov regime model ---------------------------
    let phase_tables = doc.array("phase");
    let has_markov = trace_t.get("markov_kinds").is_some()
        || trace_t.get("markov_dwell_div").is_some()
        || trace_t.get("markov_dwell_min_s").is_some();
    let trace = if has_markov {
        if !phase_tables.is_empty() {
            return Err(CompileError::PhaseWindow {
                key: "trace.markov_kinds".to_string(),
                msg: "manifest declares both [[phase]] tables and Markov trace keys"
                    .to_string(),
            });
        }
        let kinds_v = trace_t.get("markov_kinds").ok_or_else(|| {
            CompileError::MissingKey { key: "trace.markov_kinds".to_string() }
        })?;
        let Value::List(items) = kinds_v else {
            return Err(CompileError::BadValue {
                key: "trace.markov_kinds".to_string(),
                msg: format!("expected a list of kinds, got {}", kinds_v.type_name()),
            });
        };
        if items.is_empty() {
            return Err(CompileError::BadValue {
                key: "trace.markov_kinds".to_string(),
                msg: "regime kind set is empty".to_string(),
            });
        }
        let mut kinds = Vec::new();
        for item in items {
            kinds.push(parse_kind(want_str(item, "trace.markov_kinds")?,
                "trace.markov_kinds")?);
        }
        let dwell_div = opt_num(trace_t, "trace", "markov_dwell_div", 12.0)?;
        let dwell_min_secs = opt_num(trace_t, "trace", "markov_dwell_min_s", 20.0)?;
        if dwell_div <= 0.0 {
            return Err(CompileError::RateBound {
                key: "trace.markov_dwell_div".to_string(),
                msg: format!("dwell divisor {dwell_div} must be > 0"),
            });
        }
        if dwell_min_secs < 1.0 {
            return Err(CompileError::RateBound {
                key: "trace.markov_dwell_min_s".to_string(),
                msg: format!("minimum dwell {dwell_min_secs} must be >= 1 s"),
            });
        }
        TraceSpec::Markov { kinds, dwell_div, dwell_min_secs }
    } else {
        if phase_tables.is_empty() {
            return Err(CompileError::MissingKey { key: "phase".to_string() });
        }
        let mut phases = Vec::new();
        let mut fractional: Option<bool> = None;
        let mut frac_sum = 0.0;
        for (i, pt) in phase_tables.iter().enumerate() {
            let at = |k: &str| format!("phase[{i}].{k}");
            audit_keys(pt, &format!("phase[{i}]"), &["kind", "frac", "secs",
                "level_mbps"])?;
            let kind = match pt.get("kind") {
                None => return Err(CompileError::MissingKey { key: at("kind") }),
                Some(v) => parse_kind(want_str(v, &at("kind"))?, &at("kind"))?,
            };
            let level_mbps = match pt.get("level_mbps") {
                None => return Err(CompileError::MissingKey { key: at("level_mbps") }),
                Some(v) => want_num(v, &at("level_mbps"))?,
            };
            let (dur, is_frac) = match (pt.get("frac"), pt.get("secs")) {
                (Some(_), Some(_)) => {
                    return Err(CompileError::PhaseWindow {
                        key: at("secs"),
                        msg: "phase declares both `frac` and `secs`".to_string(),
                    })
                }
                (None, None) => {
                    return Err(CompileError::MissingKey { key: at("frac") })
                }
                (Some(v), None) => (want_num(v, &at("frac"))?, true),
                (None, Some(v)) => (want_num(v, &at("secs"))?, false),
            };
            let dur_key = if is_frac { at("frac") } else { at("secs") };
            match fractional {
                None => fractional = Some(is_frac),
                Some(mode) if mode != is_frac => {
                    return Err(CompileError::PhaseWindow {
                        key: dur_key,
                        msg: "cannot mix fractional and absolute phase durations"
                            .to_string(),
                    })
                }
                Some(_) => {}
            }
            if dur <= 0.0 {
                return Err(CompileError::PhaseWindow {
                    key: dur_key,
                    msg: format!("non-positive phase duration {dur}"),
                });
            }
            if is_frac {
                if dur > 1.0 {
                    return Err(CompileError::PhaseWindow {
                        key: dur_key,
                        msg: format!("fraction {dur} exceeds the mission"),
                    });
                }
                frac_sum += dur;
            }
            // Anchor levels must sit inside the band the generator clamps
            // to — Outage phases anchor between the outage floor and the
            // ceiling instead (the built-in blackouts sit at 0.05 Mbps).
            let (lo, hi) = match kind {
                PhaseKind::Outage => (OUTAGE_FLOOR_MBPS, max_mbps),
                _ => (min_mbps, max_mbps),
            };
            if !(lo..=hi).contains(&level_mbps) {
                return Err(CompileError::RateBound {
                    key: at("level_mbps"),
                    msg: format!("anchor {level_mbps} outside [{lo}, {hi}]"),
                });
            }
            phases.push((kind, dur, level_mbps));
        }
        let fractional = fractional.expect("at least one phase");
        if fractional && (frac_sum - 1.0).abs() > 1e-6 {
            return Err(CompileError::PhaseWindow {
                key: "phase".to_string(),
                msg: format!("phase fractions sum to {frac_sum}, expected 1"),
            });
        }
        TraceSpec::Phases { phases, fractional }
    };

    // ---- [link] ----------------------------------------------------------
    let link_t = doc.table("link").unwrap_or(&empty);
    audit_keys(link_t, "link", &["loss_prob", "jitter_std", "extra_latency_s"])?;
    let loss_prob = opt_num(link_t, "link", "loss_prob", 0.0)?;
    let jitter_std = opt_num(link_t, "link", "jitter_std", 0.03)?;
    let extra_latency_s = opt_num(link_t, "link", "extra_latency_s", 0.0)?;
    if !(0.0..1.0).contains(&loss_prob) {
        return Err(CompileError::RateBound {
            key: "link.loss_prob".to_string(),
            msg: format!("loss probability {loss_prob} outside [0, 1)"),
        });
    }
    if !(0.0..=1.0).contains(&jitter_std) {
        return Err(CompileError::RateBound {
            key: "link.jitter_std".to_string(),
            msg: format!("jitter stddev {jitter_std} outside [0, 1]"),
        });
    }
    if !(0.0..=10.0).contains(&extra_latency_s) {
        return Err(CompileError::RateBound {
            key: "link.extra_latency_s".to_string(),
            msg: format!("extra latency {extra_latency_s} outside [0, 10] s"),
        });
    }

    // ---- [fleet] ---------------------------------------------------------
    let fleet_t = doc.table("fleet").unwrap_or(&empty);
    audit_keys(
        fleet_t,
        "fleet",
        &["uavs", "context_every", "stagger_secs", "workers", "shards"],
    )?;
    let n_uavs = opt_usize(fleet_t, "fleet", "uavs", 1)?;
    let context_every = opt_usize(fleet_t, "fleet", "context_every", 0)?;
    let stagger_secs = opt_num(fleet_t, "fleet", "stagger_secs", 0.0)?;
    let workers = opt_usize(fleet_t, "fleet", "workers", 1)?;
    // Megafleet core: absent = the legacy single-threaded loop; present =
    // the epoch-quantized sharded scheduler (output identical for every
    // shard count, so the bound is purely a sanity rail).
    let shards = match fleet_t.get("shards") {
        None => None,
        Some(v) => Some(want_usize(v, "fleet.shards")?),
    };
    // Megafleet ceiling: the sharded core sweeps to 16k agents, so the
    // manifest bound matches the bench envelope.
    if !(1..=16384).contains(&n_uavs) {
        return Err(CompileError::FleetSpec {
            key: "fleet.uavs".to_string(),
            msg: format!("fleet size {n_uavs} outside [1, 16384]"),
        });
    }
    if !(1..=256).contains(&workers) {
        return Err(CompileError::FleetSpec {
            key: "fleet.workers".to_string(),
            msg: format!("worker count {workers} outside [1, 256]"),
        });
    }
    if !(0.0..=600.0).contains(&stagger_secs) {
        return Err(CompileError::FleetSpec {
            key: "fleet.stagger_secs".to_string(),
            msg: format!("stagger {stagger_secs} outside [0, 600] s"),
        });
    }
    if let Some(t) = shards {
        if !(1..=256).contains(&t) {
            return Err(CompileError::FleetSpec {
                key: "fleet.shards".to_string(),
                msg: format!("shard count {t} outside [1, 256]"),
            });
        }
    }

    // ---- [[intent]] schedule --------------------------------------------
    let mut schedule = Vec::new();
    let mut prev_frac = 0.0_f64;
    for (i, it) in doc.array("intent").iter().enumerate() {
        let at = |k: &str| format!("intent[{i}].{k}");
        audit_keys(it, &format!("intent[{i}]"), &["at_frac", "prompt"])?;
        let frac = match it.get("at_frac") {
            None => return Err(CompileError::MissingKey { key: at("at_frac") }),
            Some(v) => want_num(v, &at("at_frac"))?,
        };
        if !(frac > 0.0 && frac < 1.0) {
            return Err(CompileError::ScheduleOrder {
                key: at("at_frac"),
                msg: format!("switch fraction {frac} outside (0, 1)"),
            });
        }
        if frac <= prev_frac && i > 0 {
            return Err(CompileError::ScheduleOrder {
                key: at("at_frac"),
                msg: format!("switch fraction {frac} not after {prev_frac}"),
            });
        }
        prev_frac = frac;
        let prompt = match it.get("prompt") {
            None => return Err(CompileError::MissingKey { key: at("prompt") }),
            Some(v) => want_str(v, &at("prompt"))?.to_string(),
        };
        if prompt.trim().is_empty() {
            return Err(CompileError::BadValue {
                key: at("prompt"),
                msg: "empty prompt".to_string(),
            });
        }
        schedule.push((frac, prompt));
    }

    // ---- [[fault]] schedule ---------------------------------------------
    // Fraction-based like the intent schedule; every symbolic rule the
    // runtime `FaultPlan::validate` enforces in seconds is checked here in
    // fraction space first, so a bad manifest fails before any simulation.
    let mut faults = Vec::new();
    let mut prev_at = 0.0_f64;
    let mut crash_end: Vec<(usize, f64)> = Vec::new();
    for (i, ft) in doc.array("fault").iter().enumerate() {
        let at_key = |k: &str| format!("fault[{i}].{k}");
        audit_keys(
            ft,
            &format!("fault[{i}]"),
            &["kind", "cell", "at", "duration", "rate", "stall"],
        )?;
        let kind = match ft.get("kind") {
            None => return Err(CompileError::MissingKey { key: at_key("kind") }),
            Some(v) => {
                let s = want_str(v, &at_key("kind"))?;
                FaultKind::parse(s).ok_or_else(|| CompileError::FaultSchedule {
                    key: at_key("kind"),
                    msg: format!(
                        "unknown fault kind `{s}` \
                         (cell-crash|worker-stall|exec-error|wire-corrupt|session-drop)"
                    ),
                })?
            }
        };
        let cell = opt_usize(ft, &format!("fault[{i}]"), "cell", 0)?;
        if cell >= 256 {
            return Err(CompileError::FaultSchedule {
                key: at_key("cell"),
                msg: format!("cell index {cell} outside [0, 256)"),
            });
        }
        let at = match ft.get("at") {
            None => return Err(CompileError::MissingKey { key: at_key("at") }),
            Some(v) => want_num(v, &at_key("at"))?,
        };
        if !(0.0..1.0).contains(&at) {
            return Err(CompileError::FaultSchedule {
                key: at_key("at"),
                msg: format!("start fraction {at} outside [0, 1)"),
            });
        }
        if at < prev_at {
            return Err(CompileError::FaultSchedule {
                key: at_key("at"),
                msg: format!("start fraction {at} before previous fault at {prev_at}"),
            });
        }
        prev_at = at;
        let duration = opt_num(ft, &format!("fault[{i}]"), "duration", 0.0)?;
        if !(0.0..=1.0).contains(&duration) || at + duration > 1.0 + 1e-9 {
            return Err(CompileError::FaultSchedule {
                key: at_key("duration"),
                msg: format!("window [{at}, {}) leaves the mission", at + duration),
            });
        }
        let rate = opt_num(ft, &format!("fault[{i}]"), "rate", 0.0)?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(CompileError::FaultSchedule {
                key: at_key("rate"),
                msg: format!("failure rate {rate} outside [0, 1]"),
            });
        }
        let stall_secs = opt_num(ft, &format!("fault[{i}]"), "stall", 0.0)?;
        if !stall_secs.is_finite() || stall_secs < 0.0 {
            return Err(CompileError::FaultSchedule {
                key: at_key("stall"),
                msg: format!("stall {stall_secs} must be a finite non-negative latency"),
            });
        }
        match kind {
            FaultKind::CellCrash if duration <= 0.0 => {
                return Err(CompileError::FaultSchedule {
                    key: at_key("duration"),
                    msg: "a cell-crash needs a positive recovery window".to_string(),
                })
            }
            FaultKind::CellCrash => {
                if let Some((_, end)) =
                    crash_end.iter().find(|(c, end)| *c == cell && at < *end)
                {
                    return Err(CompileError::FaultSchedule {
                        key: at_key("at"),
                        msg: format!(
                            "crash window overlaps an earlier crash on cell {cell} \
                             (recovers at fraction {end})"
                        ),
                    });
                }
                crash_end.push((cell, at + duration));
            }
            FaultKind::ExecError | FaultKind::WireCorrupt if rate <= 0.0 => {
                return Err(CompileError::FaultSchedule {
                    key: at_key("rate"),
                    msg: format!("a {} fault needs rate > 0", kind.name()),
                })
            }
            FaultKind::WorkerStall if stall_secs <= 0.0 => {
                return Err(CompileError::FaultSchedule {
                    key: at_key("stall"),
                    msg: "a worker-stall fault needs stall > 0".to_string(),
                })
            }
            _ => {}
        }
        faults.push(FaultSpec { kind, cell, at, duration, rate, stall_secs });
    }

    Ok(CompiledScenario {
        name,
        summary,
        goal,
        hysteresis,
        min_dwell,
        min_mbps,
        max_mbps,
        dt,
        trace,
        loss_prob,
        jitter_std,
        extra_latency_s,
        fleet: FleetSpec { n_uavs, context_every, stagger_secs, workers, shards },
        schedule,
        faults,
    })
}

/// Compile a standalone fault-plan manifest: a document whose only content
/// is `[[fault]]` sections (plus an optional `schema`) — the `--fault-plan`
/// CLI path.  Returns fraction-based specs; bind them with
/// [`crate::faults::bind_specs`] once the mission duration is known.
pub fn compile_fault_plan_str(text: &str) -> Result<Vec<FaultSpec>, CompileError> {
    let doc = Doc::parse(text).map_err(|e| CompileError::Parse {
        path: "<inline>".to_string(),
        line: e.line,
        msg: e.msg,
    })?;
    // Reuse the scenario lowering by grafting the fault sections onto a
    // minimal valid manifest — one validation implementation, two surfaces.
    let mut host = Doc::parse(
        "name = \"fault-plan\"\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n",
    )
    .expect("static host manifest");
    if let Some((name, _)) = doc.tables.first() {
        return Err(CompileError::UnknownKey { key: format!("[{name}]") });
    }
    for key in doc.root.keys() {
        if key != "schema" {
            return Err(CompileError::UnknownKey { key: key.to_string() });
        }
    }
    for (name, tables) in doc.arrays {
        if name != "fault" {
            return Err(CompileError::UnknownKey { key: format!("[[{name}]]") });
        }
        host.arrays.push((name, tables));
    }
    Ok(lower(&host)?.faults)
}

/// Compile a standalone fault-plan manifest file (no include resolution —
/// fault plans are small enough to be self-contained).
pub fn compile_fault_plan_file(path: &Path) -> Result<Vec<FaultSpec>, CompileError> {
    let text = std::fs::read_to_string(path).map_err(|e| CompileError::Io {
        path: path.display().to_string(),
        msg: e.to_string(),
    })?;
    compile_fault_plan_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = "name = \"mini\"\n\
        [[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n";

    #[test]
    fn minimal_manifest_compiles_with_defaults() {
        let c = compile_str(MINIMAL).unwrap();
        assert_eq!(c.name, "mini");
        assert_eq!(c.summary, "");
        assert_eq!(c.goal, MissionGoal::PrioritizeAccuracy);
        assert_eq!(c.hysteresis, 0.10);
        assert_eq!(c.min_dwell, 2);
        assert_eq!((c.min_mbps, c.max_mbps, c.dt), (8.0, 20.0, 1.0));
        assert_eq!((c.loss_prob, c.jitter_std, c.extra_latency_s), (0.0, 0.03, 0.0));
        assert_eq!(c.fleet.n_uavs, 1);
        assert_eq!(c.fleet.workers, 1);
        assert_eq!(c.fleet.shards, None);
        assert!(c.schedule.is_empty());
        assert!(c.faults.is_empty());
        let sc = c.instantiate(7, 300.0);
        assert_eq!(sc.trace.phases.len(), 1);
        assert!((sc.trace.total_secs() - 300.0).abs() < 1e-9);
        assert_eq!(sc.link.seed, 7);
    }

    #[test]
    fn fleet_shards_key_parses_and_rejects() {
        let c = compile_str(
            "name = \"x\"\n[fleet]\nuavs = 8\nshards = 4\n[[phase]]\n\
             kind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n",
        )
        .unwrap();
        assert_eq!(c.fleet.shards, Some(4));
        for bad in ["shards = 0\n", "shards = 300\n", "shards = \"many\"\n"] {
            let text = format!(
                "name = \"x\"\n[fleet]\n{bad}[[phase]]\n\
                 kind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n"
            );
            assert!(compile_str(&text).is_err(), "{bad:?} should not compile");
        }
    }

    #[test]
    fn instantiate_binds_fractions_seconds_and_markov() {
        let frac = compile_str(
            "name = \"f\"\n[[phase]]\nkind = \"stable\"\nfrac = 0.25\nlevel_mbps = 16\n\
             [[phase]]\nkind = \"drop\"\nfrac = 0.75\nlevel_mbps = 9\n",
        )
        .unwrap()
        .instantiate(7, 400.0);
        assert_eq!(frac.trace.phases[0].secs.to_bits(), (0.25_f64 * 400.0).to_bits());

        let secs = compile_str(
            "name = \"s\"\n[[phase]]\nkind = \"stable\"\nsecs = 60\nlevel_mbps = 16\n\
             [[phase]]\nkind = \"drop\"\nsecs = 60\nlevel_mbps = 9\n",
        )
        .unwrap()
        .instantiate(7, 240.0);
        assert!((secs.trace.total_secs() - 240.0).abs() < 1e-9);

        let markov = compile_str(
            "name = \"m\"\n[trace]\nmarkov_kinds = [\"stable\", \"drop\"]\n\
             markov_dwell_div = 10\nmarkov_dwell_min_s = 15\n",
        )
        .unwrap()
        .instantiate(11, 600.0);
        assert!(!markov.trace.phases.is_empty());
        assert!((markov.trace.total_secs() - 600.0).abs() < 1e-9);
    }

    #[test]
    fn each_validation_pass_names_its_key() {
        let cases: [(&str, fn(&CompileError) -> bool, &str); 10] = [
            ("[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n",
             |e| matches!(e, CompileError::MissingKey { .. }), "name"),
            ("name = \"x\"\nbogus = 1\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\n\
              level_mbps = 16\n",
             |e| matches!(e, CompileError::UnknownKey { .. }), "bogus"),
            ("name = \"x\"\ngoal = \"fastest\"\n[[phase]]\nkind = \"stable\"\n\
              frac = 1.0\nlevel_mbps = 16\n",
             |e| matches!(e, CompileError::BadValue { .. }), "goal"),
            ("name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 0.6\nlevel_mbps = 16\n",
             |e| matches!(e, CompileError::PhaseWindow { .. }), "phase"),
            ("name = \"x\"\n[trace]\nmin_mbps = 12\nmax_mbps = 9\n[[phase]]\n\
              kind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n",
             |e| matches!(e, CompileError::RateBound { .. }), "trace.max_mbps"),
            ("name = \"x\"\n[fleet]\nuavs = 0\n[[phase]]\nkind = \"stable\"\n\
              frac = 1.0\nlevel_mbps = 16\n",
             |e| matches!(e, CompileError::FleetSpec { .. }), "fleet.uavs"),
            ("name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n\
              [[intent]]\nat_frac = 1.5\nprompt = \"p\"\n",
             |e| matches!(e, CompileError::ScheduleOrder { .. }), "intent[0].at_frac"),
            ("name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 0.5\nlevel_mbps = 16\n\
              [[phase]]\nkind = \"drop\"\nsecs = 60\nlevel_mbps = 9\n",
             |e| matches!(e, CompileError::PhaseWindow { .. }), "phase[1].secs"),
            ("name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n\
              [[fault]]\nkind = \"meteor\"\nat = 0.5\n",
             |e| matches!(e, CompileError::FaultSchedule { .. }), "fault[0].kind"),
            ("name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n\
              [[fault]]\nkind = \"cell-crash\"\nat = 0.2\nduration = 0.3\n\
              [[fault]]\nkind = \"cell-crash\"\nat = 0.4\nduration = 0.1\n",
             |e| matches!(e, CompileError::FaultSchedule { .. }), "fault[1].at"),
        ];
        for (text, variant_ok, key) in cases {
            let err = compile_str(text).unwrap_err();
            assert!(variant_ok(&err), "{text:?} -> {err}");
            assert_eq!(err.key_path(), Some(key), "{err}");
        }
    }

    #[test]
    fn fault_sections_lower_and_bind_to_mission_seconds() {
        let c = compile_str(
            "name = \"chaotic\"\n\
             [[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n\
             [[fault]]\nkind = \"cell-crash\"\ncell = 1\nat = 0.25\nduration = 0.1\n\
             [[fault]]\nkind = \"exec-error\"\nat = 0.5\nduration = 0.2\nrate = 0.3\n\
             [[fault]]\nkind = \"session-drop\"\nat = 0.9\n",
        )
        .unwrap();
        assert_eq!(c.faults.len(), 3);
        assert_eq!(c.faults[0].kind, FaultKind::CellCrash);
        assert_eq!(c.faults[0].cell, 1);
        let sc = c.instantiate(7, 400.0);
        assert_eq!(sc.faults.len(), 3);
        assert_eq!(sc.faults[0].window(), (100.0, 140.0));
        assert_eq!(sc.faults[1].window(), (200.0, 280.0));
        // A bound schedule passes the runtime plan validation too.
        crate::faults::FaultPlan::with_events(7, sc.faults.clone()).unwrap();
    }

    #[test]
    fn standalone_fault_plans_compile_and_reject_foreign_keys() {
        let specs = compile_fault_plan_str(
            "[[fault]]\nkind = \"wire-corrupt\"\nat = 0.1\nduration = 0.4\nrate = 0.05\n",
        )
        .unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].kind, FaultKind::WireCorrupt);
        assert!((specs[0].rate - 0.05).abs() < 1e-12);
        // Anything beyond `[[fault]]` (and `schema`) is a foreign key here.
        let err = compile_fault_plan_str("name = \"x\"\n[[fault]]\nkind = \"session-drop\"\nat = 0.5\n")
            .unwrap_err();
        assert!(matches!(err, CompileError::UnknownKey { .. }), "{err}");
        let err =
            compile_fault_plan_str("[[fault]]\nkind = \"worker-stall\"\nat = 0.1\nduration = 0.2\n")
                .unwrap_err();
        assert_eq!(err.key_path(), Some("fault[0].stall"), "{err}");
    }

    #[test]
    fn include_is_rejected_inline_and_parse_errors_carry_lines() {
        let err = compile_str("include = \"base.toml\"\nname = \"x\"\n").unwrap_err();
        assert!(matches!(err, CompileError::Io { .. }), "{err}");
        let err = compile_str("name = \"x\"\n???\n").unwrap_err();
        match err {
            CompileError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("expected parse error, got {other}"),
        }
    }
}
