//! Wire format for UAV -> server packets.
//!
//! The Insight payload is the tanh-bounded bottleneck code quantized to int8
//! (fixed scale 127 — matching the straight-through quantizer the bottleneck
//! was trained with in python/compile/train.py), plus the CLIP tokens, also
//! int8-quantized with a per-packet scale.  A CRC32 protects the payload.
//!
//! `wire_bytes` carries the paper-scale payload size used by the link model
//! (Table 3: 2.92 / 1.35 / 0.83 MB) — see netsim::link for why.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

pub const MAGIC: u32 = 0x41565259; // "AVRY"
pub const VERSION: u16 = 1;

/// Which stream this packet belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamKind {
    Context = 0,
    Insight = 1,
}

/// A UAV->server packet before/after wire serialization.
#[derive(Clone, Debug)]
pub struct Packet {
    pub kind: StreamKind,
    /// Sequence number assigned by the edge pipeline.
    pub seq: u64,
    /// Virtual capture timestamp (seconds).
    pub t_capture: f64,
    /// Insight only: tier index into the LUT (identifies the tail artifact).
    pub tier: u8,
    /// Insight only: split point k.
    pub split: u8,
    /// Insight only: quantized bottleneck code (tokens x M).
    pub code_q: Vec<i8>,
    pub code_shape: (usize, usize),
    /// Quantized CLIP tokens (clip_tokens x clip_dim) + their scale.
    pub clip_q: Vec<i8>,
    pub clip_shape: (usize, usize),
    pub clip_scale: f32,
    /// Paper-scale bytes the link model charges for this packet.
    pub wire_bytes: f64,
}

/// Quantize a tanh-bounded f32 tensor to int8 at fixed scale 127.
pub fn quantize_code(t: &Tensor) -> Result<(Vec<i8>, (usize, usize))> {
    let data = t.as_f32()?;
    let shape = t.shape();
    if shape.len() != 2 {
        bail!("code must be rank 2, got {:?}", shape);
    }
    let q = data.iter().map(|&x| (x.clamp(-1.0, 1.0) * 127.0).round() as i8).collect();
    Ok((q, (shape[0], shape[1])))
}

/// Dequantize a fixed-scale int8 code back to f32.
pub fn dequantize_code(q: &[i8], shape: (usize, usize)) -> Result<Tensor> {
    let data: Vec<f32> = q.iter().map(|&v| v as f32 / 127.0).collect();
    Tensor::f32(vec![shape.0, shape.1], data)
}

/// Quantize an arbitrary-range f32 tensor with a per-tensor scale.
pub fn quantize_scaled(t: &Tensor) -> Result<(Vec<i8>, (usize, usize), f32)> {
    let data = t.as_f32()?;
    let shape = t.shape();
    if shape.len() != 2 {
        bail!("tensor must be rank 2, got {:?}", shape);
    }
    let max = data.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let scale = max / 127.0;
    let q = data.iter().map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8).collect();
    Ok((q, (shape[0], shape[1]), scale))
}

pub fn dequantize_scaled(q: &[i8], shape: (usize, usize), scale: f32) -> Result<Tensor> {
    let data: Vec<f32> = q.iter().map(|&v| v as f32 * scale).collect();
    Tensor::f32(vec![shape.0, shape.1], data)
}

impl Packet {
    /// Actual (mini-scale) serialized payload size in bytes.
    pub fn real_bytes(&self) -> usize {
        32 + self.code_q.len() + self.clip_q.len()
    }

    /// Serialize to the length-prefixed wire encoding (used by the TCP
    /// transport and by tests; the in-process transport passes `Packet`
    /// structs directly).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.real_bytes() + 64);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind as u8);
        out.push(self.tier);
        out.push(self.split);
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.t_capture.to_le_bytes());
        out.extend_from_slice(&self.wire_bytes.to_le_bytes());
        out.extend_from_slice(&(self.code_shape.0 as u32).to_le_bytes());
        out.extend_from_slice(&(self.code_shape.1 as u32).to_le_bytes());
        out.extend_from_slice(&(self.clip_shape.0 as u32).to_le_bytes());
        out.extend_from_slice(&(self.clip_shape.1 as u32).to_le_bytes());
        out.extend_from_slice(&self.clip_scale.to_le_bytes());
        let code_bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.code_q.as_ptr() as *const u8, self.code_q.len())
        };
        let clip_bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.clip_q.as_ptr() as *const u8, self.clip_q.len())
        };
        out.extend_from_slice(code_bytes);
        out.extend_from_slice(clip_bytes);
        let crc = crate::util::crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Packet> {
        if buf.len() < 57 {
            bail!("packet too short: {} bytes", buf.len());
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let got = crate::util::crc32(body);
        if want != got {
            bail!("packet CRC mismatch: want {want:08x} got {got:08x}");
        }
        let mut off = 0usize;
        let mut take = |n: usize| -> Result<&[u8]> {
            if off + n > body.len() {
                bail!("packet truncated at offset {off}");
            }
            let s = &body[off..off + n];
            off += n;
            Ok(s)
        };
        let magic = u32::from_le_bytes(take(4)?.try_into().unwrap());
        if magic != MAGIC {
            bail!("bad packet magic {magic:08x}");
        }
        let version = u16::from_le_bytes(take(2)?.try_into().unwrap());
        if version != VERSION {
            bail!("unsupported packet version {version}");
        }
        let kind = match take(1)?[0] {
            0 => StreamKind::Context,
            1 => StreamKind::Insight,
            other => bail!("bad stream kind {other}"),
        };
        let tier = take(1)?[0];
        let split = take(1)?[0];
        let seq = u64::from_le_bytes(take(8)?.try_into().unwrap());
        let t_capture = f64::from_le_bytes(take(8)?.try_into().unwrap());
        let wire_bytes = f64::from_le_bytes(take(8)?.try_into().unwrap());
        let c0 = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let c1 = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let k0 = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let k1 = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let clip_scale = f32::from_le_bytes(take(4)?.try_into().unwrap());
        let code_raw = take(c0 * c1)?;
        let code_q: Vec<i8> = code_raw.iter().map(|&b| b as i8).collect();
        let clip_raw = take(k0 * k1)?;
        let clip_q: Vec<i8> = clip_raw.iter().map(|&b| b as i8).collect();
        Ok(Packet {
            kind,
            seq,
            t_capture,
            tier,
            split,
            code_q,
            code_shape: (c0, c1),
            clip_q,
            clip_shape: (k0, k1),
            clip_scale,
            wire_bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_packet() -> Packet {
        Packet {
            kind: StreamKind::Insight,
            seq: 42,
            t_capture: 3.5,
            tier: 1,
            split: 1,
            code_q: vec![-127, 0, 64, 127, 1, -3],
            code_shape: (2, 3),
            clip_q: vec![5, -5, 100, -100],
            clip_shape: (2, 2),
            clip_scale: 0.031,
            wire_bytes: 1.35e6,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let p = sample_packet();
        let buf = p.encode();
        let q = Packet::decode(&buf).unwrap();
        assert_eq!(q.seq, 42);
        assert_eq!(q.kind, StreamKind::Insight);
        assert_eq!(q.code_q, p.code_q);
        assert_eq!(q.clip_q, p.clip_q);
        assert_eq!(q.code_shape, (2, 3));
        assert!((q.wire_bytes - 1.35e6).abs() < 1e-9);
    }

    #[test]
    fn corrupted_crc_rejected() {
        let mut buf = sample_packet().encode();
        let n = buf.len();
        buf[n / 2] ^= 0xFF;
        assert!(Packet::decode(&buf).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let buf = sample_packet().encode();
        assert!(Packet::decode(&buf[..buf.len() - 9]).is_err());
        assert!(Packet::decode(&[]).is_err());
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let t = Tensor::f32(vec![2, 4], vec![-1.0, -0.5, -0.1, 0.0, 0.1, 0.5, 0.9, 1.0])
            .unwrap();
        let (q, shape) = quantize_code(&t).unwrap();
        let back = dequantize_code(&q, shape).unwrap();
        for (a, b) in t.as_f32().unwrap().iter().zip(back.as_f32().unwrap()) {
            assert!((a - b).abs() <= 0.5 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn scaled_quantize_roundtrip() {
        let t = Tensor::f32(vec![1, 4], vec![-8.0, 2.0, 0.0, 7.5]).unwrap();
        let (q, shape, scale) = quantize_scaled(&t).unwrap();
        let back = dequantize_scaled(&q, shape, scale).unwrap();
        for (a, b) in t.as_f32().unwrap().iter().zip(back.as_f32().unwrap()) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6, "{a} vs {b}");
        }
    }
}
