//! Edge (UAV-side) pipeline: turns a captured scene into a transmissible
//! packet for the selected stream/tier, running the AOT head artifacts
//! through the PJRT engine and charging device-model costs.
//!
//! Wire sizing (DESIGN.md "Substitutions" #4): Insight packets carry the
//! paper's Table 3 payload bytes so feasibility crossovers match the paper;
//! Context packets carry a fixed 0.1 MB CLIP-feature payload (the paper
//! gives no number, only "lightweight"; at 8–20 Mbps this keeps the context
//! stream compute-bound — its rate is limited by the 6.4x-faster on-device
//! CLIP pass, not the uplink, exactly as §5.2.2 describes).

use std::borrow::Cow;

use anyhow::{Context, Result};

use crate::coordinator::{Lut, TierId};
use crate::dataset::Scene;
use crate::energy::{DeviceModel, StageCost};
use crate::packet::{quantize_code, quantize_scaled, Packet, StreamKind};
use crate::runtime::Engine;

/// Paper-scale wire bytes charged for a Context packet.
pub const CONTEXT_WIRE_BYTES: f64 = 0.1e6;

/// Artifact naming helpers (must match aot.py).  The `_name` variants
/// borrow from the interned table in [`crate::runtime`] — zero allocation
/// for every split the table covers, which is all of them in practice
/// (`format!` fallback above `runtime::MAX_STATIC_SPLIT`).
pub fn head_artifact_name(split: usize, tier: TierId) -> Cow<'static, str> {
    match crate::runtime::head_name(split, tier) {
        Some(s) => Cow::Borrowed(s),
        None => Cow::Owned(format!("head_sp{split}_{}", tier.name())),
    }
}

pub fn tail_artifact_name(split: usize, tier: TierId) -> Cow<'static, str> {
    match crate::runtime::tail_name(split, tier) {
        Some(s) => Cow::Borrowed(s),
        None => Cow::Owned(format!("tail_sp{split}_{}", tier.name())),
    }
}

pub fn head_artifact(split: usize, tier: TierId) -> String {
    head_artifact_name(split, tier).into_owned()
}

pub fn tail_artifact(split: usize, tier: TierId) -> String {
    tail_artifact_name(split, tier).into_owned()
}

/// The UAV-side pipeline.
pub struct EdgePipeline {
    pub engine: Engine,
    pub device: DeviceModel,
    pub lut: Lut,
    seq: u64,
}

impl EdgePipeline {
    pub fn new(engine: Engine, device: DeviceModel, lut: Lut) -> Self {
        Self { engine, device, lut, seq: 0 }
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Run the Insight head at (split, tier) on a scene and packetize.
    /// Returns the packet plus the on-device cost charged by the Jetson model.
    pub fn capture_insight(
        &mut self,
        scene: &Scene,
        split: usize,
        tier: TierId,
        t_capture: f64,
    ) -> Result<(Packet, StageCost)> {
        let artifact = head_artifact_name(split, tier);
        // Borrowed dispatch: the scene image is never cloned on this path —
        // the inline backend reads it in place.
        let outs = self
            .engine
            .execute(&artifact, "shared", std::slice::from_ref(&scene.image))
            .with_context(|| format!("running {artifact}"))?;
        // outputs: code, clip_tokens, clip_pooled
        let (code_q, code_shape) = quantize_code(&outs[0])?;
        let (clip_q, clip_shape, clip_scale) = quantize_scaled(&outs[1])?;
        let pkt = Packet {
            kind: StreamKind::Insight,
            seq: self.next_seq(),
            t_capture,
            tier: tier.index() as u8,
            split: split as u8,
            code_q,
            code_shape,
            clip_q,
            clip_shape,
            clip_scale,
            wire_bytes: self.lut.entry(tier).wire_bytes,
        };
        Ok((pkt, self.device.insight_edge(split)))
    }

    /// Run the Context (CLIP-only) path and packetize.
    pub fn capture_context(&mut self, scene: &Scene, t_capture: f64) -> Result<(Packet, StageCost)> {
        let outs = self
            .engine
            .execute("context_edge", "shared", std::slice::from_ref(&scene.image))
            .context("running context_edge")?;
        let (clip_q, clip_shape, clip_scale) = quantize_scaled(&outs[0])?;
        let pkt = Packet {
            kind: StreamKind::Context,
            seq: self.next_seq(),
            t_capture,
            tier: 0,
            split: 0,
            code_q: Vec::new(),
            code_shape: (0, 0),
            clip_q,
            clip_shape,
            clip_scale,
            wire_bytes: CONTEXT_WIRE_BYTES,
        };
        Ok((pkt, self.device.context_edge()))
    }
}
