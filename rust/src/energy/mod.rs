//! Device latency/energy substrate: a Jetson AGX Xavier (MODE_30W_ALL)
//! model calibrated to the paper's published split-point profile.
//!
//! We cannot run on a Jetson (repro gate), so mission latencies and energy
//! come from this calibrated model while *numerics* come from real PJRT
//! execution of the artifacts.  Calibration anchors (paper §5.2.1, Fig 8):
//!
//! | point        | latency (s) | energy (J) |
//! |--------------|-------------|------------|
//! | split@1      | 0.2318      | 3.12       |
//! | split@11     | 0.9441      | 13.81      |
//! | split@29     | 2.5044      | 43.34      |
//! | full SAM     | 11.8 x sp1  | 16.6 x sp1 |
//!
//! The full-SAM anchor uses the Fig 8 caption ratios (11.8x / 16.6x), which
//! are consistent with the 93.98% energy-saving headline (1 - 1/16.6);
//! §5.2.1's prose "12.75 J and 12.7262 s" contradicts both and is treated
//! as a typo — see DESIGN.md "Substitutions" #3.
//!
//! Our mini-LISA backbone has 8 blocks; split k in [1,8] maps onto the
//! paper's 31-deep profile by depth fraction: p(k) = 1 + (k-1)*30/7.

/// Latency + energy of one pipeline stage on the edge device.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageCost {
    pub latency_s: f64,
    pub energy_j: f64,
}

impl StageCost {
    pub fn add(self, other: StageCost) -> StageCost {
        StageCost {
            latency_s: self.latency_s + other.latency_s,
            energy_j: self.energy_j + other.energy_j,
        }
    }
}

/// Calibrated device model.
#[derive(Clone, Debug)]
pub struct DeviceModel {
    /// (paper split depth, latency s, energy J) anchors, ascending depth.
    anchors: Vec<(f64, f64, f64)>,
    /// Full-SAM-onboard multipliers over split@1.
    full_latency_mult: f64,
    full_energy_mult: f64,
    /// Context (CLIP-only) on-device speedup over the Insight head (§5.2.2).
    context_speedup: f64,
    /// Radio transmit power (W) charged against tx time.
    pub radio_watts: f64,
    /// Mini-LISA backbone depth (manifest `depth`).
    pub model_depth: usize,
    /// Paper backbone depth the anchors are expressed in.
    paper_depth: usize,
}

impl DeviceModel {
    /// Jetson AGX Xavier, MODE_30W_ALL (the paper's fixed P_cfg).
    pub fn jetson_mode_30w(model_depth: usize) -> Self {
        Self {
            anchors: vec![
                (1.0, 0.2318, 3.12),
                (11.0, 0.9441, 13.81),
                (29.0, 2.5044, 43.34),
                (31.0, 2.6778, 46.62),
            ],
            full_latency_mult: 11.8,
            full_energy_mult: 16.6,
            context_speedup: 6.4,
            radio_watts: 1.5,
            model_depth,
            paper_depth: 31,
        }
    }

    /// Map our split index k in [1, model_depth] to paper depth.
    pub fn paper_depth_of(&self, k: usize) -> f64 {
        if self.model_depth <= 1 {
            return 1.0;
        }
        1.0 + (k as f64 - 1.0) * (self.paper_depth as f64 - 1.0)
            / (self.model_depth as f64 - 1.0)
    }

    fn interp(&self, depth: f64) -> StageCost {
        let a = &self.anchors;
        if depth <= a[0].0 {
            return StageCost { latency_s: a[0].1, energy_j: a[0].2 };
        }
        for w in a.windows(2) {
            let (d0, l0, e0) = w[0];
            let (d1, l1, e1) = w[1];
            if depth <= d1 {
                let t = (depth - d0) / (d1 - d0);
                return StageCost {
                    latency_s: l0 + (l1 - l0) * t,
                    energy_j: e0 + (e1 - e0) * t,
                };
            }
        }
        let (_, l, e) = *a.last().unwrap();
        StageCost { latency_s: l, energy_j: e }
    }

    /// On-device cost of the Insight head at our split k (prefix + bottleneck
    /// encode + CLIP; the paper's profile includes all of this in split@k).
    pub fn insight_edge(&self, k: usize) -> StageCost {
        self.interp(self.paper_depth_of(k))
    }

    /// On-device cost of running the FULL SAM backbone (+decoder) onboard —
    /// the full-edge baseline the 93.98% headline compares against.
    pub fn full_edge(&self) -> StageCost {
        let sp1 = self.interp(1.0);
        StageCost {
            latency_s: sp1.latency_s * self.full_latency_mult,
            energy_j: sp1.energy_j * self.full_energy_mult,
        }
    }

    /// On-device cost of the Context (CLIP-only) path: 6.4x faster than the
    /// Insight head at split@1, energy scaled with time at fixed power.
    pub fn context_edge(&self) -> StageCost {
        let sp1 = self.interp(1.0);
        StageCost {
            latency_s: sp1.latency_s / self.context_speedup,
            energy_j: sp1.energy_j / self.context_speedup,
        }
    }

    /// Radio energy for a transmission occupying the uplink `tx_secs`.
    pub fn tx_energy(&self, tx_secs: f64) -> f64 {
        self.radio_watts * tx_secs
    }

    /// Cloud-side tail latency (RTX 6000 Ada class server; fast relative to
    /// the edge — it shapes end-to-end latency, not uplink-bound PPS).
    pub fn cloud_tail_latency(&self, k: usize) -> f64 {
        // Deeper split => less work on the server.
        let frac = 1.0 - (k as f64 - 1.0) / self.paper_depth as f64;
        0.05 + 0.08 * frac.max(0.0)
    }

    /// [`DeviceModel::cloud_tail_latency`] under micro-batched serving
    /// (DESIGN.md "Cloud serving layer"): the 0.05 s per-request setup
    /// component (scheduler dispatch, weight activation, KV-cache prefill)
    /// amortizes across a batch of up to `batch_max` compatible requests;
    /// the per-packet tail compute does not.  `batch_max <= 1` reproduces
    /// the unbatched latency exactly.
    pub fn cloud_tail_latency_batched(&self, k: usize, batch_max: usize) -> f64 {
        if batch_max <= 1 {
            return self.cloud_tail_latency(k);
        }
        let frac = 1.0 - (k as f64 - 1.0) / self.paper_depth as f64;
        0.05 / batch_max as f64 + 0.08 * frac.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sp1_matches_paper_anchor() {
        let m = DeviceModel::jetson_mode_30w(8);
        let c = m.insight_edge(1);
        assert!((c.latency_s - 0.2318).abs() < 1e-9);
        assert!((c.energy_j - 3.12).abs() < 1e-9);
    }

    #[test]
    fn batched_tail_latency_amortizes_setup_only() {
        let m = DeviceModel::jetson_mode_30w(8);
        for k in [1usize, 4, 8] {
            // batch_max 1 (and 0) reproduce the unbatched latency exactly.
            assert_eq!(m.cloud_tail_latency_batched(k, 1), m.cloud_tail_latency(k));
            assert_eq!(m.cloud_tail_latency_batched(k, 0), m.cloud_tail_latency(k));
            // Larger batches amortize exactly the 0.05 s setup component.
            let b8 = m.cloud_tail_latency_batched(k, 8);
            assert!((m.cloud_tail_latency(k) - b8 - (0.05 - 0.05 / 8.0)).abs() < 1e-12);
            assert!(b8 > 0.0);
        }
    }

    #[test]
    fn depth_mapping_endpoints() {
        let m = DeviceModel::jetson_mode_30w(8);
        assert!((m.paper_depth_of(1) - 1.0).abs() < 1e-9);
        assert!((m.paper_depth_of(8) - 31.0).abs() < 1e-9);
    }

    #[test]
    fn energy_monotone_in_depth() {
        let m = DeviceModel::jetson_mode_30w(8);
        let mut last = 0.0;
        for k in 1..=8 {
            let e = m.insight_edge(k).energy_j;
            assert!(e > last, "k={k} e={e}");
            last = e;
        }
    }

    #[test]
    fn headline_energy_saving_is_93_98_pct() {
        let m = DeviceModel::jetson_mode_30w(8);
        let save = 1.0 - m.insight_edge(1).energy_j / m.full_edge().energy_j;
        assert!((save - 0.9398).abs() < 0.001, "saving {save}");
    }

    #[test]
    fn context_is_6_4x_faster() {
        let m = DeviceModel::jetson_mode_30w(8);
        let ratio = m.insight_edge(1).latency_s / m.context_edge().latency_s;
        assert!((ratio - 6.4).abs() < 1e-9);
    }

    #[test]
    fn sp11_equivalent_interpolates() {
        // Our k that maps nearest paper depth 11 should cost ~13.8 J.
        let m = DeviceModel::jetson_mode_30w(8);
        // paper_depth_of(3) = 1 + 2*30/7 = 9.57; interp between anchors.
        let c = m.insight_edge(3);
        assert!(c.energy_j > 3.12 && c.energy_j < 13.81);
    }
}
