//! Comparison baselines from the paper's evaluation:
//!
//! * **Static tiers** — handled by `streams::Policy::Static` (fixed
//!   High-Accuracy / Balanced / High-Throughput).
//! * **Raw image compression** (§5.2.1, footnote b) — instead of split@1 +
//!   learned bottleneck, downsample + int8-quantize the *image* to the same
//!   payload bytes, reconstruct server-side, and run the full pipeline
//!   there.  The paper's 11.2% headline is split@1 vs this baseline at
//!   matched payload.
//! * **Full edge** — run the whole pipeline onboard (the 93.98% energy
//!   headline's comparator).
//! * **Cloud only** — ship the uncompressed representation (paper-scale
//!   10.49 MB SAM activation) and run everything remotely.

use anyhow::{Context, Result};

use crate::coordinator::{classify_intent, Lut, TierId};
use crate::dataset::Dataset;
use crate::energy::DeviceModel;
use crate::eval::{mask_iou, IouAccumulator};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Bilinear-resize a (s, s, 3) image to (d, d, 3).
pub fn resize_bilinear(img: &[f32], s: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d * d * 3];
    if d == 0 || s == 0 {
        return out;
    }
    let scale = if d > 1 { (s - 1) as f32 / (d - 1) as f32 } else { 0.0 };
    for y in 0..d {
        let fy = y as f32 * scale;
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(s - 1);
        let wy = fy - y0 as f32;
        for x in 0..d {
            let fx = x as f32 * scale;
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(s - 1);
            let wx = fx - x0 as f32;
            for c in 0..3 {
                let p00 = img[(y0 * s + x0) * 3 + c];
                let p01 = img[(y0 * s + x1) * 3 + c];
                let p10 = img[(y1 * s + x0) * 3 + c];
                let p11 = img[(y1 * s + x1) * 3 + c];
                let top = p00 + (p01 - p00) * wx;
                let bot = p10 + (p11 - p10) * wx;
                out[(y * d + x) * 3 + c] = top + (bot - top) * wy;
            }
        }
    }
    out
}

/// Degrade an image exactly as the raw-compression uplink would: bilinear
/// downsample to `side`, uint8-quantize (the wire), upsample back.
pub fn raw_compress_roundtrip(img: &Tensor, side: usize) -> Result<Tensor> {
    let s = img.shape()[0];
    let data = img.as_f32()?;
    let down = resize_bilinear(data, s, side);
    let q: Vec<f32> = down
        .iter()
        .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() / 255.0)
        .collect();
    let up = resize_bilinear(&q, side, s);
    Tensor::f32(vec![s, s, 3], up)
}

/// Side length whose int8 image payload matches a tier's real payload bytes.
pub fn matched_side(lut: &Lut, tier: TierId) -> usize {
    let payload = lut.entry(tier).real_payload_bytes as f64;
    ((payload / 3.0).sqrt().floor() as usize).max(4)
}

/// Accuracy of the raw-image-compression baseline at a tier-matched payload,
/// evaluated with the full pipeline server-side (weight `set` per corpus).
pub fn eval_raw_compression(
    engine: &Engine,
    dataset: &Dataset,
    lut: &Lut,
    tier: TierId,
) -> Result<(f64, IouAccumulator)> {
    let side = matched_side(lut, tier);
    let mut acc = IouAccumulator::default();
    for scene in &dataset.scenes {
        for (class_id, prompt) in &scene.prompts {
            let intent = classify_intent(prompt);
            let degraded = raw_compress_roundtrip(&scene.image, side)?;
            let pids = Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone())?;
            let outs = engine
                .execute_owned("full_pipeline", dataset.corpus.weight_set(), vec![degraded, pids])
                .context("raw-compression full_pipeline")?;
            acc.push(mask_iou(outs[0].as_f32()?, &scene.masks[*class_id], 0.0));
        }
    }
    Ok((acc.avg_iou(), acc))
}

/// Accuracy of the AVERY split path (head+tail through the real artifacts,
/// including wire quantization) at a tier, over a dataset.
pub fn eval_split_path(
    engine: &Engine,
    dataset: &Dataset,
    lut: &Lut,
    device: &DeviceModel,
    split: usize,
    tier: TierId,
) -> Result<(f64, IouAccumulator)> {
    use crate::cloud::CloudServer;
    use crate::edge::EdgePipeline;
    let mut edge = EdgePipeline::new(engine.clone(), device.clone(), lut.clone());
    let server = CloudServer::new(engine.clone());
    let mut acc = IouAccumulator::default();
    for scene in &dataset.scenes {
        for (class_id, prompt) in &scene.prompts {
            let intent = classify_intent(prompt);
            let (pkt, _) = edge.capture_insight(scene, split, tier, 0.0)?;
            let resp = server.process(&pkt, &intent.token_ids, dataset.corpus.weight_set())?;
            let logits = resp.mask_logits.as_ref().expect("insight mask");
            acc.push(mask_iou(logits.as_f32()?, &scene.masks[*class_id], 0.0));
        }
    }
    Ok((acc.avg_iou(), acc))
}

/// Accuracy of the full (uncompressed) pipeline — the full-edge baseline's
/// quality and the raw-compression baseline's upper bound.
pub fn eval_full_pipeline(
    engine: &Engine,
    dataset: &Dataset,
) -> Result<(f64, IouAccumulator)> {
    let mut acc = IouAccumulator::default();
    for scene in &dataset.scenes {
        for (class_id, prompt) in &scene.prompts {
            let intent = classify_intent(prompt);
            let pids = Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone())?;
            let outs = engine
                .execute_owned(
                    "full_pipeline",
                    dataset.corpus.weight_set(),
                    vec![scene.image.clone(), pids],
                )
                .context("full_pipeline")?;
            acc.push(mask_iou(outs[0].as_f32()?, &scene.masks[*class_id], 0.0));
        }
    }
    Ok((acc.avg_iou(), acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resize_identity() {
        let img: Vec<f32> = (0..4 * 4 * 3).map(|i| i as f32 / 48.0).collect();
        let out = resize_bilinear(&img, 4, 4);
        for (a, b) in img.iter().zip(&out) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn resize_down_up_loses_detail() {
        // A checkerboard loses contrast through 2x down/up.
        let s = 8;
        let mut img = vec![0.0f32; s * s * 3];
        for y in 0..s {
            for x in 0..s {
                let v = if (x + y) % 2 == 0 { 1.0 } else { 0.0 };
                for c in 0..3 {
                    img[(y * s + x) * 3 + c] = v;
                }
            }
        }
        let down = resize_bilinear(&img, s, 4);
        let up = resize_bilinear(&down, 4, s);
        let err: f32 = img.iter().zip(&up).map(|(a, b)| (a - b).abs()).sum::<f32>()
            / img.len() as f32;
        assert!(err > 0.05, "expected detail loss, err {err}");
    }

    #[test]
    fn matched_side_shrinks_with_tier() {
        let lut = {
            let mut l = Lut::paper();
            // paper() has no real payloads; fill plausible ones.
            for (e, p) in l.tiers.iter_mut().zip([3136usize, 1920, 1472]) {
                e.real_payload_bytes = p;
            }
            l
        };
        let ha = matched_side(&lut, TierId::HighAccuracy);
        let bal = matched_side(&lut, TierId::Balanced);
        let ht = matched_side(&lut, TierId::HighThroughput);
        assert!(ha > bal && bal > ht, "{ha} {bal} {ht}");
    }

    #[test]
    fn quantization_in_roundtrip() {
        let img = Tensor::f32(vec![8, 8, 3], vec![0.5; 192]).unwrap();
        let out = raw_compress_roundtrip(&img, 4).unwrap();
        for &v in out.as_f32().unwrap() {
            assert!((v - 0.5).abs() < 1.0 / 255.0 + 1e-6);
        }
    }
}
