//! Segmentation-quality metrics, matching LISA's protocol [17] as the paper
//! uses it: **gIoU** (mean of per-image IoU) and **cIoU** (cumulative
//! intersection over cumulative union), with "Average IoU" their mean.
//! Must agree with python/compile/train.py::iou_stats (cross-checked by the
//! parity integration test).

/// Accumulates per-image IoU across a run.
#[derive(Clone, Debug, Default)]
pub struct IouAccumulator {
    per_image: Vec<f64>,
    inter_sum: f64,
    union_sum: f64,
}

/// Binary-mask IoU components for one image.
#[derive(Clone, Copy, Debug)]
pub struct IouSample {
    pub intersection: f64,
    pub union: f64,
}

/// Compute intersection/union between a predicted logit map (mask = logits >
/// threshold) and a binary GT mask.
pub fn mask_iou(pred_logits: &[f32], gt: &[f32], threshold: f32) -> IouSample {
    debug_assert_eq!(pred_logits.len(), gt.len());
    let mut inter = 0.0f64;
    let mut union = 0.0f64;
    for (&p, &g) in pred_logits.iter().zip(gt) {
        let pm = p > threshold;
        let gm = g > 0.5;
        if pm && gm {
            inter += 1.0;
        }
        if pm || gm {
            union += 1.0;
        }
    }
    IouSample { intersection: inter, union }
}

impl IouAccumulator {
    pub fn push(&mut self, s: IouSample) {
        // Empty-GT-and-empty-pred counts as perfect (matches python).
        let iou = if s.union > 0.0 { s.intersection / s.union } else { 1.0 };
        self.per_image.push(iou);
        self.inter_sum += s.intersection;
        self.union_sum += s.union;
    }

    pub fn n(&self) -> usize {
        self.per_image.len()
    }

    /// Mean per-image IoU.
    pub fn giou(&self) -> f64 {
        if self.per_image.is_empty() {
            return 0.0;
        }
        self.per_image.iter().sum::<f64>() / self.per_image.len() as f64
    }

    /// Cumulative-intersection / cumulative-union.
    pub fn ciou(&self) -> f64 {
        if self.union_sum <= 0.0 {
            return 0.0;
        }
        self.inter_sum / self.union_sum
    }

    /// The paper's "Average IoU" = mean(gIoU, cIoU).
    pub fn avg_iou(&self) -> f64 {
        0.5 * (self.giou() + self.ciou())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_is_one() {
        let gt = vec![0.0, 1.0, 1.0, 0.0];
        let logits = vec![-5.0, 5.0, 5.0, -5.0];
        let mut acc = IouAccumulator::default();
        acc.push(mask_iou(&logits, &gt, 0.0));
        assert!((acc.giou() - 1.0).abs() < 1e-12);
        assert!((acc.ciou() - 1.0).abs() < 1e-12);
        assert!((acc.avg_iou() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_prediction_is_zero() {
        let gt = vec![1.0, 1.0, 0.0, 0.0];
        let logits = vec![-5.0, -5.0, 5.0, 5.0];
        let mut acc = IouAccumulator::default();
        acc.push(mask_iou(&logits, &gt, 0.0));
        assert_eq!(acc.giou(), 0.0);
        assert_eq!(acc.ciou(), 0.0);
    }

    #[test]
    fn half_overlap() {
        let gt = vec![1.0, 1.0, 0.0, 0.0];
        let logits = vec![5.0, -5.0, -5.0, -5.0];
        let mut acc = IouAccumulator::default();
        acc.push(mask_iou(&logits, &gt, 0.0));
        assert!((acc.giou() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn giou_vs_ciou_weighting_differs() {
        // Image A: tiny mask, perfect. Image B: big mask, half right.
        let mut acc = IouAccumulator::default();
        acc.push(IouSample { intersection: 1.0, union: 1.0 });
        acc.push(IouSample { intersection: 50.0, union: 100.0 });
        assert!((acc.giou() - 0.75).abs() < 1e-12);
        assert!((acc.ciou() - 51.0 / 101.0).abs() < 1e-12);
    }

    #[test]
    fn empty_gt_empty_pred_is_perfect() {
        let mut acc = IouAccumulator::default();
        acc.push(mask_iou(&[-1.0, -1.0], &[0.0, 0.0], 0.0));
        assert_eq!(acc.giou(), 1.0);
    }
}
