//! Byte transports between the UAV edge process and the cloud server.
//!
//! The virtual-time missions call edge/cloud directly (the link simulator
//! supplies timing), but the system also runs as two real processes: the
//! `distributed_serve` example wires `EdgePipeline` to `CloudServer` over
//! TCP loopback with this length-prefixed framing.  No tokio in the offline
//! crate set — blocking std::net + threads.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{bail, Context, Result};

/// Maximum frame we will accept (guards against corrupt length prefixes).
pub const MAX_FRAME: usize = 64 << 20;

/// Server reply frame sent instead of a response when the admission
/// controller sheds a session request (`CloudPool::serve_session` with a
/// bounded queue under [`crate::cloud::AdmissionPolicy::Shed`]).  Four
/// bytes, so it can never be confused with a real response frame — those
/// always carry at least two u32 section counts (8 bytes).
pub const BUSY_FRAME: &[u8] = b"busy";

/// A frame that ends mid-section: the typed signature of a session dying
/// mid-frame (or a corrupt length prefix declaring more bytes than are
/// present).  Both wire decoders ([`decode_request`] here and
/// [`crate::cloud::decode_reply`]/[`crate::cloud::decode_response`])
/// surface this instead of a generic error, so retry/failover layers can
/// downcast and tell a cut stream from a real protocol violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TruncatedStream {
    /// Which frame section was cut short.
    pub section: &'static str,
    /// Bytes the section header declared.
    pub wanted: usize,
    /// Bytes actually remaining in the frame.
    pub got: usize,
}

impl std::fmt::Display for TruncatedStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "truncated stream: {} section of {} bytes exceeds the {} bytes remaining in the frame",
            self.section, self.wanted, self.got
        )
    }
}

impl std::error::Error for TruncatedStream {}

/// A bidirectional message transport.
pub trait Transport {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;
}

/// In-process transport (paired mpsc byte channels).
pub struct InProc {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl InProc {
    /// Create a connected pair (a <-> b).
    pub fn pair() -> (InProc, InProc) {
        let (atx, brx) = channel();
        let (btx, arx) = channel();
        (InProc { tx: atx, rx: arx }, InProc { tx: btx, rx: brx })
    }
}

impl Transport for InProc {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx.send(frame.to_vec()).map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("peer closed"))
    }
}

/// TCP transport with u32-LE length-prefixed frames.
pub struct Tcp {
    stream: TcpStream,
}

impl Tcp {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    pub fn from_stream(stream: TcpStream) -> Self {
        stream.set_nodelay(true).ok();
        Self { stream }
    }

    /// Bind + accept one peer (the example server's accept loop).
    pub fn accept_one<A: ToSocketAddrs>(addr: A) -> Result<(Self, std::net::SocketAddr)> {
        let listener = TcpListener::bind(addr).context("binding")?;
        let local = listener.local_addr()?;
        let (stream, _) = listener.accept().context("accepting")?;
        Ok((Self::from_stream(stream), local))
    }
}

impl Transport for Tcp {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if frame.len() > MAX_FRAME {
            bail!("frame too large: {}", frame.len());
        }
        self.stream.write_all(&(frame.len() as u32).to_le_bytes())?;
        self.stream.write_all(frame)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len_bytes = [0u8; 4];
        self.stream.read_exact(&mut len_bytes).context("reading frame length")?;
        let len = u32::from_le_bytes(len_bytes) as usize;
        if len > MAX_FRAME {
            bail!("incoming frame too large: {len}");
        }
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf).context("reading frame body")?;
        Ok(buf)
    }
}

/// A request frame for distributed serving: packet bytes + prompt + weight
/// set.  An empty `set` defers to the session default pinned by a
/// `hello <set>` frame (see `CloudPool::serve_session`).
pub fn encode_request(packet_bytes: &[u8], prompt: &str, set: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(packet_bytes.len() + prompt.len() + 16);
    out.extend_from_slice(&(packet_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(packet_bytes);
    out.extend_from_slice(&(prompt.len() as u32).to_le_bytes());
    out.extend_from_slice(prompt.as_bytes());
    out.extend_from_slice(&(set.len() as u32).to_le_bytes());
    out.extend_from_slice(set.as_bytes());
    out
}

pub fn decode_request(frame: &[u8]) -> Result<(Vec<u8>, String, String)> {
    let mut off = 0usize;
    // Every section length is checked against the bytes actually remaining
    // BEFORE any slicing — a corrupt or hostile u32 prefix (up to 4 GiB of
    // declared payload) is rejected here instead of driving downstream
    // allocation or offset arithmetic.  The same guard covers short reply
    // frames (e.g. the 4-byte `busy` frame) mistakenly fed to this decoder.
    // The shortfall surfaces as the typed [`TruncatedStream`], naming the
    // section the stream died in.
    let mut take = |n: usize, section: &'static str| -> Result<&[u8]> {
        if n > frame.len() - off {
            return Err(TruncatedStream { section, wanted: n, got: frame.len() - off }.into());
        }
        let s = &frame[off..off + n];
        off += n;
        Ok(s)
    };
    let plen = u32::from_le_bytes(take(4, "packet-length")?.try_into().unwrap()) as usize;
    let pkt = take(plen, "packet")?.to_vec();
    let slen = u32::from_le_bytes(take(4, "prompt-length")?.try_into().unwrap()) as usize;
    let prompt = String::from_utf8(take(slen, "prompt")?.to_vec()).context("prompt utf8")?;
    let klen = u32::from_le_bytes(take(4, "set-length")?.try_into().unwrap()) as usize;
    let set = String::from_utf8(take(klen, "set")?.to_vec()).context("set utf8")?;
    Ok((pkt, prompt, set))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProc::pair();
        a.send(b"hello").unwrap();
        assert_eq!(b.recv().unwrap(), b"hello");
        b.send(b"world").unwrap();
        assert_eq!(a.recv().unwrap(), b"world");
    }

    #[test]
    fn tcp_roundtrip_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = Tcp::from_stream(stream);
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap();
        });
        let mut c = Tcp::connect(addr).unwrap();
        c.send(b"ping-pong-payload").unwrap();
        assert_eq!(c.recv().unwrap(), b"ping-pong-payload");
        server.join().unwrap();
    }

    #[test]
    fn tcp_many_concurrent_clients() {
        // The fleet-serving shape: one listener, a session thread per
        // client, many clients hammering frames concurrently.  Every frame
        // must come back intact on its own session — no cross-talk.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        const CLIENTS: usize = 8;
        const FRAMES: usize = 50;
        let server = std::thread::spawn(move || {
            let mut sessions = Vec::new();
            for _ in 0..CLIENTS {
                let (stream, _) = listener.accept().unwrap();
                sessions.push(std::thread::spawn(move || {
                    let mut t = Tcp::from_stream(stream);
                    while let Ok(frame) = t.recv() {
                        if frame == b"bye" {
                            break;
                        }
                        t.send(&frame).unwrap();
                    }
                }));
            }
            for s in sessions {
                s.join().unwrap();
            }
        });
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut t = Tcp::connect(addr).unwrap();
                    for i in 0..FRAMES {
                        let msg = format!("client {c} frame {i} {}", "x".repeat(c * 17 + i));
                        t.send(msg.as_bytes()).unwrap();
                        assert_eq!(t.recv().unwrap(), msg.as_bytes(), "c{c} f{i}");
                    }
                    t.send(b"bye").unwrap();
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        server.join().unwrap();
    }

    #[test]
    fn inproc_concurrent_sessions() {
        // Multiple independent InProc sessions driven from worker threads;
        // each pair stays isolated.
        const SESSIONS: usize = 6;
        let mut handles = Vec::new();
        for s in 0..SESSIONS {
            let (mut client, mut server) = InProc::pair();
            let srv = std::thread::spawn(move || {
                for _ in 0..20 {
                    let f = server.recv().unwrap();
                    server.send(&f).unwrap();
                }
            });
            handles.push(std::thread::spawn(move || {
                for i in 0..20 {
                    let msg = format!("s{s}-{i}");
                    client.send(msg.as_bytes()).unwrap();
                    assert_eq!(client.recv().unwrap(), msg.as_bytes());
                }
                srv.join().unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn request_roundtrip() {
        let frame = encode_request(b"\x01\x02\x03", "find people", "ft");
        let (pkt, prompt, set) = decode_request(&frame).unwrap();
        assert_eq!(pkt, vec![1, 2, 3]);
        assert_eq!(prompt, "find people");
        assert_eq!(set, "ft");
    }

    #[test]
    fn truncated_request_rejected() {
        let frame = encode_request(b"abc", "p", "s");
        assert!(decode_request(&frame[..frame.len() - 2]).is_err());
    }

    #[test]
    fn every_request_cut_point_surfaces_typed_truncation() {
        // A session dying mid-frame can cut the stream at ANY byte.  Every
        // strict prefix must surface the dedicated TruncatedStream error —
        // never a generic one, never a bogus success.
        let frame = encode_request(b"\x01\x02\x03\x04\x05", "find people", "ft");
        for cut in 0..frame.len() {
            let err = decode_request(&frame[..cut])
                .expect_err(&format!("prefix of {cut} bytes decoded"));
            let t = err
                .downcast_ref::<TruncatedStream>()
                .unwrap_or_else(|| panic!("cut at {cut}: untyped error {err:#}"));
            assert!(t.wanted > t.got, "cut at {cut}: {t:?}");
        }
        // The full frame still decodes.
        assert!(decode_request(&frame).is_ok());
    }

    #[test]
    fn oversized_section_lengths_rejected() {
        // A 4 GiB packet-section prefix in a tiny frame: rejected before
        // any slicing or allocation.
        let mut frame = Vec::new();
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(b"abc");
        let err = decode_request(&frame).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        // Same for the prompt and set sections.
        for (prompt_len, set_len) in [(u32::MAX, 0u32), (1, u32::MAX)] {
            let mut frame = Vec::new();
            frame.extend_from_slice(&2u32.to_le_bytes());
            frame.extend_from_slice(b"pk");
            frame.extend_from_slice(&prompt_len.to_le_bytes());
            frame.extend_from_slice(b"p");
            frame.extend_from_slice(&set_len.to_le_bytes());
            assert!(decode_request(&frame).is_err(), "{prompt_len} {set_len}");
        }
        // The short busy reply frame cannot be misparsed as a request.
        assert!(decode_request(BUSY_FRAME).is_err());
    }
}
