//! Parser for `artifacts/manifest.txt` and `artifacts/lut.txt` — the
//! line-based metadata emitted by `python/compile/aot.py` (the offline crate
//! set has no serde/JSON, so the build path emits both JSON for humans and
//! this trivially-parsable form for the runtime).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::parse_dims;

/// Element dtype of a parameter or input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One leading HLO parameter (a weight leaf, in exact pytree order).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One runtime input (follows all weight parameters in HLO parameter order).
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

/// One AOT-compiled execution path.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo: PathBuf,
    /// weight-set name ("shared" / "orig" / "ft") -> weight binary path.
    pub weights: BTreeMap<String, PathBuf>,
    pub params: Vec<ParamSpec>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub golden: BTreeMap<String, PathBuf>,
}

impl ArtifactSpec {
    pub fn weight_numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// The artifact index produced by `make artifacts`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub img: usize,
    pub tokens: usize,
    pub dim: usize,
    pub depth: usize,
    pub clip_tokens: usize,
    pub clip_dim: usize,
    pub prompt_tokens: usize,
    pub vocab: usize,
    pub num_classes: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, artifacts_dir)
    }

    pub fn parse(text: &str, root: &Path) -> Result<Self> {
        let mut m = Manifest {
            root: root.to_path_buf(),
            img: 0,
            tokens: 0,
            dim: 0,
            depth: 0,
            clip_tokens: 0,
            clip_dim: 0,
            prompt_tokens: 0,
            vocab: 0,
            num_classes: 0,
            artifacts: BTreeMap::new(),
        };
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let mut it = line.split_whitespace();
            let Some(tag) = it.next() else { continue };
            let ctx = || format!("manifest.txt line {}", lineno + 1);
            match tag {
                "meta" => {
                    let kv: Vec<&str> = it.collect();
                    for pair in kv.chunks(2) {
                        let [k, v] = pair else { bail!("{}: odd meta pairs", ctx()) };
                        let v: usize = v.parse().with_context(ctx)?;
                        match *k {
                            "img" => m.img = v,
                            "tokens" => m.tokens = v,
                            "dim" => m.dim = v,
                            "depth" => m.depth = v,
                            "clip_tokens" => m.clip_tokens = v,
                            "clip_dim" => m.clip_dim = v,
                            "prompt_tokens" => m.prompt_tokens = v,
                            "vocab" => m.vocab = v,
                            "num_classes" => m.num_classes = v,
                            _ => {}
                        }
                    }
                }
                "artifact" => {
                    if cur.is_some() {
                        bail!("{}: nested artifact", ctx());
                    }
                    cur = Some(ArtifactSpec {
                        name: it.next().context("artifact name")?.to_string(),
                        hlo: PathBuf::new(),
                        weights: BTreeMap::new(),
                        params: Vec::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                        golden: BTreeMap::new(),
                    });
                }
                "hlo" => {
                    cur.as_mut().with_context(ctx)?.hlo =
                        root.join(it.next().context("hlo path")?);
                }
                "weights" => {
                    let a = cur.as_mut().with_context(ctx)?;
                    let set = it.next().context("weights set")?.to_string();
                    a.weights.insert(set, root.join(it.next().context("weights path")?));
                }
                "param" => {
                    let a = cur.as_mut().with_context(ctx)?;
                    a.params.push(ParamSpec {
                        name: it.next().context("param name")?.to_string(),
                        dtype: DType::parse(it.next().context("param dtype")?)?,
                        dims: parse_dims(it.next().context("param dims")?),
                    });
                }
                "input" => {
                    let a = cur.as_mut().with_context(ctx)?;
                    a.inputs.push(InputSpec {
                        name: it.next().context("input name")?.to_string(),
                        dtype: DType::parse(it.next().context("input dtype")?)?,
                        dims: parse_dims(it.next().context("input dims")?),
                    });
                }
                "output" => {
                    let a = cur.as_mut().with_context(ctx)?;
                    a.outputs.push(it.next().context("output name")?.to_string());
                }
                "golden" => {
                    let a = cur.as_mut().with_context(ctx)?;
                    let set = it.next().context("golden set")?.to_string();
                    a.golden.insert(set, root.join(it.next().context("golden path")?));
                }
                "end" => {
                    let a = cur.take().with_context(ctx)?;
                    m.artifacts.insert(a.name.clone(), a);
                }
                other => bail!("{}: unknown tag {other}", ctx()),
            }
        }
        if cur.is_some() {
            bail!("manifest.txt: unterminated artifact record");
        }
        if m.artifacts.is_empty() {
            bail!("manifest.txt: no artifacts — rerun `make artifacts`");
        }
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }

    /// Names of all Insight head artifacts, sorted.
    pub fn head_names(&self) -> Vec<String> {
        self.artifacts.keys().filter(|k| k.starts_with("head_")).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
meta img 64 tokens 64 dim 128 depth 8 clip_tokens 16 clip_dim 64 prompt_tokens 16 vocab 512 num_classes 2
artifact head_sp1_balanced
hlo hlo/head_sp1_balanced.hlo.txt
weights shared weights/head_sp1_balanced.shared.bin
param w0.patch_w float32 192,128
param w0.blocks.wqkv float32 1,128,384
input img float32 64,64,3
output code
output clip_tokens
golden shared golden/head_sp1_balanced.shared.bin
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.img, 64);
        assert_eq!(m.depth, 8);
        let a = m.artifact("head_sp1_balanced").unwrap();
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.params[1].dims, vec![1, 128, 384]);
        assert_eq!(a.weight_numel(), 192 * 128 + 128 * 384);
        assert_eq!(a.inputs[0].name, "img");
        assert_eq!(a.outputs.len(), 2);
        assert!(a.golden.contains_key("shared"));
    }

    #[test]
    fn rejects_unterminated() {
        let bad = "artifact x\nhlo h.txt\n";
        assert!(Manifest::parse(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_unknown_tag() {
        let bad = "meta img 64\nbogus line here\n";
        assert!(Manifest::parse(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn missing_artifact_lookup_fails() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
