//! Dual-stream scheduler: runs Insight and Context missions over a shared
//! virtual clock, combining the controller (Algorithm 1), the link
//! simulator, the device model and real PJRT execution of the artifacts.
//!
//! Timing model (documented in DESIGN.md): the uplink is the serial
//! resource.  The edge head capture of packet k+1 overlaps the transmission
//! of packet k, so the per-packet cycle is `max(edge_latency, tx_time)` —
//! which reduces to the paper's throughput formula f = (B/8)/data_size
//! whenever transmission dominates (it does for every Insight tier in the
//! 8–20 Mbps range).  Numerics are real: every `exec_every`-th delivered
//! packet actually executes the head+tail artifacts and scores IoU against
//! the GT mask.

use anyhow::Result;

use crate::cloud::CloudServer;
use crate::coordinator::{
    classify_intent, ControllerDecision, ControllerError, Intent, IntentLevel, Lut,
    MissionGoal, RuntimeState, SplitController, TierId,
};
use crate::dataset::{Corpus, Dataset, RoundRobin};
use crate::edge::EdgePipeline;
use crate::energy::DeviceModel;
use crate::eval::{mask_iou, IouAccumulator};
use crate::netsim::{BandwidthEstimator, Link};
use crate::runtime::Engine;
use crate::util::Rng;

/// Which policy drives tier selection in a mission run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// AVERY's adaptive controller (Algorithm 1).
    Avery,
    /// A static baseline pinned to one tier (paper's three baselines).
    Static(TierId),
}

impl Policy {
    pub fn label(self) -> String {
        match self {
            Policy::Avery => "AVERY".to_string(),
            Policy::Static(t) => format!("Static {}", t.display()),
        }
    }
}

/// Mission configuration.
#[derive(Clone, Debug)]
pub struct MissionConfig {
    pub duration_secs: f64,
    pub goal: MissionGoal,
    /// F_I — minimum Insight update rate (paper deployment: 0.5 PPS).
    pub min_insight_pps: f64,
    /// Context stream ceiling (compute-bound; see DeviceModel).
    pub max_context_pps: f64,
    /// Execute the HLO pipeline on every Nth delivered packet (1 = all).
    pub exec_every: usize,
    /// Controller hysteresis margin (0 = verbatim Algorithm 1).
    pub hysteresis: f64,
    /// Fixed split point (the paper fixes split@1 after §5.2.1).
    pub split: usize,
    pub seed: u64,
}

impl Default for MissionConfig {
    fn default() -> Self {
        Self {
            duration_secs: 1200.0,
            goal: MissionGoal::PrioritizeAccuracy,
            min_insight_pps: 0.5,
            max_context_pps: 0.0, // filled from device model when 0
            exec_every: 1,
            hysteresis: 0.0,
            split: 1,
            seed: 7,
        }
    }
}

/// One per-decision-epoch telemetry row (drives Fig 9 a/b/d).
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub t: f64,
    pub bandwidth_true_mbps: f64,
    pub bandwidth_est_mbps: f64,
    /// Selected tier (None = no feasible tier this epoch).
    pub tier: Option<TierId>,
}

/// One per-packet telemetry row (drives Fig 9 c / Fig 10).
#[derive(Clone, Copy, Debug)]
pub struct PacketRecord {
    pub t_send: f64,
    pub t_deliver: f64,
    pub tier: TierId,
    pub corpus: Corpus,
    /// IoU if this packet was actually executed (exec_every sampling).
    pub iou: Option<f64>,
    pub edge_energy_j: f64,
    pub tx_energy_j: f64,
}

/// Aggregates over one mission run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub policy: String,
    pub delivered: u64,
    pub executed: u64,
    pub avg_pps: f64,
    pub avg_iou: f64,
    pub avg_iou_orig: f64,
    pub avg_iou_ft: f64,
    pub giou: f64,
    pub ciou: f64,
    pub total_energy_j: f64,
    pub energy_per_packet_j: f64,
    /// Virtual seconds spent in each tier (HA, BAL, HT).
    pub tier_secs: [f64; 3],
    pub switches: u64,
    pub infeasible_epochs: u64,
}

/// Full result of an Insight mission run.
#[derive(Clone, Debug)]
pub struct InsightRun {
    pub epochs: Vec<EpochRecord>,
    pub packets: Vec<PacketRecord>,
    pub summary: RunSummary,
}

/// Run the 20-minute (by default) Insight-stream mission (paper §5.3).
pub fn run_insight_mission(
    engine: &Engine,
    datasets: &[&Dataset],
    lut: &Lut,
    device: &DeviceModel,
    link: &mut Link,
    cfg: &MissionConfig,
    policy: Policy,
) -> Result<InsightRun> {
    let max_ctx = if cfg.max_context_pps > 0.0 {
        cfg.max_context_pps
    } else {
        1.0 / device.context_edge().latency_s
    };
    let mut controller = SplitController::new(lut.clone(), cfg.min_insight_pps, max_ctx);
    controller.hysteresis = cfg.hysteresis;

    let mut edge = EdgePipeline::new(engine.clone(), device.clone(), lut.clone());
    let server = CloudServer::new(engine.clone());
    let mut rr = RoundRobin::new(datasets.to_vec());
    let mut probe_noise = Rng::new(cfg.seed ^ 0x5EED);

    let mut epochs = Vec::new();
    let mut packets = Vec::new();
    let mut acc_all = IouAccumulator::default();
    let mut acc_orig = IouAccumulator::default();
    let mut acc_ft = IouAccumulator::default();
    let mut tier_secs = [0.0f64; 3];
    let mut total_energy = 0.0f64;
    let mut infeasible = 0u64;
    let mut delivered = 0u64;
    let mut executed = 0u64;
    let mut estimator = BandwidthEstimator::new(0.4);
    // Prime the estimator with one probe so the first decision is informed.
    estimator.observe(link.bandwidth_at(0.0));

    // A grounded Insight intent drives the whole run (the paper's dynamic
    // experiment evaluates the Insight stream; intent gating itself is
    // exercised by the context mission and unit tests).
    let insight_intent = classify_intent("highlight the stranded people");

    let mut t = 0.0f64;
    let mut next_epoch_log = 0.0f64;
    while t < cfg.duration_secs {
        // ---- Sense: periodic probe + goodput feedback (EWMA). ----
        let true_bw = link.bandwidth_at(t);
        let probe = (true_bw * (1.0 + 0.02 * probe_noise.normal())).max(0.1);
        let est = estimator.observe(probe);

        // ---- Decide (Gate/Evaluate/Select or pinned static tier). ----
        let decision = match policy {
            Policy::Avery => {
                let state = RuntimeState {
                    bandwidth_mbps: est,
                    power_mode: "MODE_30W_ALL",
                    intent: insight_intent.clone(),
                };
                match controller.select_configuration(&state, cfg.goal) {
                    Ok(ControllerDecision::Insight { tier, .. }) => Some(tier),
                    Ok(ControllerDecision::Context { .. }) => unreachable!("insight intent"),
                    Err(ControllerError::NoFeasibleInsightTier) => None,
                }
            }
            Policy::Static(tier) => Some(tier),
        };

        // Per-second epoch telemetry (Fig 9 a/b).
        while next_epoch_log <= t {
            epochs.push(EpochRecord {
                t: next_epoch_log,
                bandwidth_true_mbps: link.bandwidth_at(next_epoch_log),
                bandwidth_est_mbps: est,
                tier: decision,
            });
            next_epoch_log += 1.0;
        }

        let Some(tier) = decision else {
            infeasible += 1;
            t += 1.0; // wait one epoch and re-sense
            continue;
        };

        // ---- Stream one Insight packet. ----
        let Some(item) = rr.next_item() else { break };
        let intent = classify_intent(item.prompt);
        let class_id = intent.target_class.unwrap_or(item.class_id);
        let (pkt, cost) = edge.capture_insight(item.scene, cfg.split, tier, t)?;
        let tx = link.transmit(t, pkt.wire_bytes);
        estimator.observe(tx.goodput_mbps);
        let cycle = cost.latency_s.max(tx.tx_secs);
        let t_deliver = t + cycle + device.cloud_tail_latency(cfg.split);
        let tx_energy = device.tx_energy(tx.tx_secs);
        total_energy += cost.energy_j + tx_energy;
        tier_secs[tier.index()] += cycle;

        let mut iou = None;
        if tx.delivered {
            delivered += 1;
            // Sample packets for real HLO execution with probability
            // 1/exec_every via the deterministic rng — a modulo would alias
            // against the strict generic/flood round-robin and starve one
            // corpus of accuracy samples.
            let sample = cfg.exec_every <= 1
                || probe_noise.below(cfg.exec_every) == 0;
            if sample {
                let resp = server.process(&pkt, &intent.token_ids, item.corpus.weight_set())?;
                let logits = resp.mask_logits.as_ref().expect("insight mask");
                let s = mask_iou(logits.as_f32()?, &item.scene.masks[class_id], 0.0);
                let mut one = IouAccumulator::default();
                one.push(s);
                iou = Some(one.giou());
                acc_all.push(s);
                match item.corpus {
                    Corpus::Generic => acc_orig.push(s),
                    Corpus::Flood => acc_ft.push(s),
                }
                executed += 1;
            }
        }
        packets.push(PacketRecord {
            t_send: t,
            t_deliver,
            tier,
            corpus: item.corpus,
            iou,
            edge_energy_j: cost.energy_j,
            tx_energy_j: tx_energy,
        });
        t += cycle;
    }

    let avg_pps = delivered as f64 / cfg.duration_secs;
    let summary = RunSummary {
        policy: policy.label(),
        delivered,
        executed,
        avg_pps,
        avg_iou: acc_all.avg_iou(),
        avg_iou_orig: acc_orig.avg_iou(),
        avg_iou_ft: acc_ft.avg_iou(),
        giou: acc_all.giou(),
        ciou: acc_all.ciou(),
        total_energy_j: total_energy,
        energy_per_packet_j: if delivered > 0 {
            total_energy / delivered as f64
        } else {
            0.0
        },
        tier_secs,
        switches: controller.switches,
        infeasible_epochs: infeasible,
    };
    Ok(InsightRun { epochs, packets, summary })
}

/// Result of a Context-stream mission (the §5.2.2 characterization + the
/// paper's triage workflow of §4.3).
#[derive(Clone, Debug, Default)]
pub struct ContextRun {
    pub updates: u64,
    pub achieved_pps: f64,
    /// Presence-answer accuracy against GT (both classes).
    pub presence_accuracy: f64,
    pub edge_latency_s: f64,
    pub insight_edge_latency_s: f64,
    /// On-device speedup of Context over the Insight head (paper: 6.4x).
    pub speedup: f64,
}

/// Run a Context-stream mission: stream context queries at the
/// compute-bound rate and score the text-level presence answers.
pub fn run_context_mission(
    engine: &Engine,
    datasets: &[&Dataset],
    lut: &Lut,
    device: &DeviceModel,
    duration_secs: f64,
    prompts: &[&str],
) -> Result<ContextRun> {
    let mut edge = EdgePipeline::new(engine.clone(), device.clone(), lut.clone());
    let server = CloudServer::new(engine.clone());
    let mut rr = RoundRobin::new(datasets.to_vec());
    let ctx_cost = device.context_edge();
    let rate = 1.0 / ctx_cost.latency_s;
    let mut t = 0.0;
    let mut updates = 0u64;
    let mut correct = 0u64;
    let mut total = 0u64;
    let mut pi = 0usize;
    while t < duration_secs {
        let Some(item) = rr.next_item() else { break };
        let prompt = prompts[pi % prompts.len()];
        pi += 1;
        let intent = classify_intent(prompt);
        debug_assert_eq!(intent.level, IntentLevel::Context);
        let (pkt, cost) = edge.capture_context(item.scene, t)?;
        let resp = server.process(&pkt, &intent.token_ids, item.corpus.weight_set())?;
        for (cls, &logit) in resp.presence.iter().enumerate() {
            let gt = item.scene.masks[cls].iter().any(|&m| m > 0.5);
            if (logit > 0.0) == gt {
                correct += 1;
            }
            total += 1;
        }
        updates += 1;
        t += cost.latency_s;
    }
    Ok(ContextRun {
        updates,
        achieved_pps: updates as f64 / duration_secs.max(1e-9),
        presence_accuracy: correct as f64 / total.max(1) as f64,
        edge_latency_s: ctx_cost.latency_s,
        insight_edge_latency_s: device.insight_edge(1).latency_s,
        speedup: device.insight_edge(1).latency_s / ctx_cost.latency_s,
    })
    .map(|mut r| {
        r.achieved_pps = r.achieved_pps.min(rate);
        r
    })
}

/// Intent used by the Insight mission — exposed for tests.
pub fn default_insight_intent() -> Intent {
    classify_intent("highlight the stranded people")
}
