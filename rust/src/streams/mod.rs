//! Dual-stream scheduler: per-UAV mission state machines over a virtual
//! clock, combining the controller (Algorithm 1), the link simulator, the
//! device model and real PJRT execution of the artifacts.
//!
//! The unit of execution is the [`UavAgent`] — one UAV's Sense → Gate →
//! Evaluate → Select → Stream cycle, owning its [`SplitController`],
//! [`EdgePipeline`], [`BandwidthEstimator`] and operator intent.  The
//! single-UAV missions ([`run_insight_mission`]) drive one agent over a
//! dedicated [`Link`]; the fleet scheduler ([`fleet`]) drives N
//! heterogeneous agents over a contended
//! [`SharedLink`](crate::netsim::SharedLink) in global event order.
//!
//! Timing model (documented in DESIGN.md §"Timing model"): the uplink is the
//! serial resource.  The edge head capture of packet k+1 overlaps the
//! transmission of packet k, so the per-packet cycle is
//! `max(edge_latency, tx_time)` — which reduces to the paper's throughput
//! formula f = (B/8)/data_size whenever transmission dominates (it does for
//! every Insight tier in the 8–20 Mbps range).  Numerics are real: every
//! `exec_every`-th delivered packet actually executes the head+tail
//! artifacts and scores IoU against the GT mask.

pub mod fleet;
pub mod shard;

use anyhow::Result;

use crate::cloud::{CloudServer, ServeError, ServePackets, Served};
use crate::coordinator::{
    classify_intent, ControllerDecision, ControllerError, Intent, IntentLevel, Lut,
    MissionGoal, RuntimeState, SplitController, TierId,
};
use crate::dataset::{Corpus, Dataset, RoundRobin};
use crate::edge::EdgePipeline;
use crate::energy::DeviceModel;
use crate::eval::{mask_iou, IouAccumulator};
use crate::netsim::{BandwidthEstimator, Link, Uplink};
use crate::packet::{Packet, StreamKind};
use crate::runtime::Engine;
use crate::util::Rng;

/// Which policy drives tier selection in a mission run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// AVERY's adaptive controller (Algorithm 1).
    Avery,
    /// A static baseline pinned to one tier (paper's three baselines).
    Static(TierId),
}

impl Policy {
    pub fn label(self) -> String {
        match self {
            Policy::Avery => "AVERY".to_string(),
            Policy::Static(t) => format!("Static {}", t.display()),
        }
    }
}

/// Which stream a [`UavAgent`] flies (its standing operator intent).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UavRole {
    /// High-fidelity grounded segmentation over the uplink (tier-adaptive).
    Insight,
    /// High-frequency coarse awareness (compute-bound, lightweight packets).
    Context,
}

impl UavRole {
    pub fn name(self) -> &'static str {
        match self {
            UavRole::Insight => "insight",
            UavRole::Context => "context",
        }
    }
}

/// Mission configuration.
#[derive(Clone, Debug)]
pub struct MissionConfig {
    pub duration_secs: f64,
    pub goal: MissionGoal,
    /// F_I — minimum Insight update rate (paper deployment: 0.5 PPS).
    pub min_insight_pps: f64,
    /// Context stream ceiling (compute-bound; see DeviceModel).
    pub max_context_pps: f64,
    /// Execute the HLO pipeline on every Nth delivered packet (1 = all).
    pub exec_every: usize,
    /// Controller hysteresis margin (0 = verbatim Algorithm 1).
    pub hysteresis: f64,
    /// Controller minimum dwell decisions after a tier switch (0 =
    /// verbatim Algorithm 1; scenario missions use 2 — see DESIGN.md).
    pub min_dwell: u64,
    /// Fixed split point (the paper fixes split@1 after §5.2.1).
    pub split: usize,
    pub seed: u64,
    /// Cloud micro-batch bound the serving layer runs with (1 = unbatched):
    /// the timing model amortizes the per-request tail setup across the
    /// batch ([`crate::energy::DeviceModel::cloud_tail_latency_batched`]).
    pub batch_max: usize,
    /// Per-request retry budget against retryable cloud failures (sheds
    /// and injected faults): 0 = off, errors propagate exactly as before
    /// the chaos layer existed.
    pub retry_budget: u32,
    /// First retry backoff (virtual seconds); doubles per attempt.
    pub retry_backoff_secs: f64,
    /// Deadline on accumulated backoff: a retry whose wait would pass this
    /// is abandoned instead (`f64::INFINITY` = budget-only).
    pub retry_deadline_secs: f64,
    /// Graceful degradation: when the cloud is unreachable past the retry
    /// budget, an Insight request degrades to edge-local Context-tier
    /// execution (the paper's functional split as a fallback path) instead
    /// of being lost.
    pub degrade: bool,
}

impl Default for MissionConfig {
    fn default() -> Self {
        Self {
            duration_secs: 1200.0,
            goal: MissionGoal::PrioritizeAccuracy,
            min_insight_pps: 0.5,
            max_context_pps: 0.0, // filled from device model when 0
            exec_every: 1,
            hysteresis: 0.0,
            min_dwell: 0,
            split: 1,
            seed: 7,
            batch_max: 1,
            retry_budget: 0,
            retry_backoff_secs: 0.05,
            retry_deadline_secs: f64::INFINITY,
            degrade: false,
        }
    }
}

/// One timed operator re-tasking: at mission-relative time `t` the operator
/// issues a new standing prompt.  The prompt's classified [`IntentLevel`]
/// drives the agent's stream (Context ↔ Insight) from that point on — the
/// runtime re-plans through the existing controller, exactly as the paper's
/// §4.3 triage-escalation workflow describes, but on a schedule.
#[derive(Clone, Debug)]
pub struct IntentSwitch {
    /// Virtual time (seconds) the new intent takes effect.
    pub t: f64,
    /// The operator's new standing prompt.
    pub prompt: String,
}

impl IntentSwitch {
    pub fn new(t: f64, prompt: &str) -> Self {
        Self { t, prompt: prompt.to_string() }
    }
}

/// One per-decision-epoch telemetry row (drives Fig 9 a/b/d).
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub t: f64,
    pub bandwidth_true_mbps: f64,
    pub bandwidth_est_mbps: f64,
    /// Selected tier (None = Context stream, or no feasible Insight tier).
    pub tier: Option<TierId>,
    /// The stream the agent was flying this epoch (intent schedules can
    /// change it mid-mission).
    pub level: IntentLevel,
}

/// One per-packet telemetry row (drives Fig 9 c / Fig 10).
#[derive(Clone, Copy, Debug)]
pub struct PacketRecord {
    pub t_send: f64,
    pub t_deliver: f64,
    pub tier: TierId,
    pub corpus: Corpus,
    /// IoU if this packet was actually executed (exec_every sampling).
    pub iou: Option<f64>,
    pub edge_energy_j: f64,
    pub tx_energy_j: f64,
}

/// Aggregates over one mission run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub policy: String,
    pub delivered: u64,
    pub executed: u64,
    /// Executions that scored an Insight mask (= IoU sample count) —
    /// distinct from `executed` once an intent schedule has the agent
    /// answering Context queries part-time.
    pub insight_executed: u64,
    pub avg_pps: f64,
    pub avg_iou: f64,
    pub avg_iou_orig: f64,
    pub avg_iou_ft: f64,
    pub giou: f64,
    pub ciou: f64,
    pub total_energy_j: f64,
    pub energy_per_packet_j: f64,
    /// Virtual seconds spent in each tier (HA, BAL, HT).
    pub tier_secs: [f64; 3],
    pub switches: u64,
    /// Operator re-taskings applied from the intent schedule.
    pub intent_switches: u64,
    pub infeasible_epochs: u64,
    /// Served requests answered from the cloud's content-addressed response
    /// cache (0 unless the serving layer's cache is enabled).
    pub cache_hits: u64,
    /// Cluster ring hops charged to this agent's requests — overflow-spill
    /// retries plus sibling-cache round trips (0 at `--cells 1`).
    pub spill_hops: u64,
    /// Served requests answered from a sibling replica's cache instead of
    /// the home cell's (0 unless `--replicas` > 1).
    pub remote_hits: u64,
    /// Bitmask of cluster cells that answered this agent (cell `i` sets
    /// bit `min(i, 63)`); the popcount is the per-UAV cells-hit telemetry.
    pub cells_mask: u64,
    /// Sampled serve attempts that entered the resilience layer: the
    /// conservation denominator (`executed + shed_lost + degraded +
    /// abandoned == captures`, pinned by `rust/tests/chaos.rs`).
    pub captures: u64,
    /// Retry attempts issued against retryable cloud failures.
    pub retries: u64,
    /// Requests lost to a terminal shed (admission refusal past the
    /// retry budget).
    pub shed_lost: u64,
    /// Insight requests that degraded to edge-local Context-tier
    /// execution after the cloud stayed unreachable past the budget.
    pub degraded: u64,
    /// Requests abandoned outright (unreachable cloud, degradation off
    /// or not applicable).
    pub abandoned: u64,
    /// Virtual seconds spent inside degraded handling (terminal backoff
    /// plus the edge fallback execution).
    pub degraded_secs: f64,
    /// Virtual seconds spent backing off between retry attempts.
    pub retry_wait_secs: f64,
}

/// Full result of an Insight mission run.
#[derive(Clone, Debug)]
pub struct InsightRun {
    pub epochs: Vec<EpochRecord>,
    pub packets: Vec<PacketRecord>,
    pub summary: RunSummary,
}

/// One UAV's mission state machine.  `step` advances exactly one
/// sense/decide/stream cycle at the agent's current virtual time `t`; a
/// scheduler (single-UAV loop or the fleet event loop) decides who steps
/// next by comparing agents' clocks.
pub struct UavAgent<'a> {
    pub id: usize,
    /// Current stream (follows the intent schedule at runtime).
    pub role: UavRole,
    /// Stream the agent launched with (fleet composition telemetry).
    pub launch_role: UavRole,
    pub policy: Policy,
    /// Virtual time the agent joined the mission (staggered fleet starts).
    pub start_t: f64,
    /// The agent's clock: virtual time of its next cycle.
    pub t: f64,
    cfg: MissionConfig,
    intent: Intent,
    /// Timed operator re-taskings, sorted by time; applied as the agent's
    /// clock passes each entry.
    schedule: Vec<IntentSwitch>,
    sched_i: usize,
    pub intent_switches: u64,
    /// True once a scheduled re-tasking has been applied: from then on the
    /// operator's standing intent (not each dataset item's own prompt)
    /// drives Insight serving and scoring.  Launch intents keep the
    /// original per-item behavior so default missions are unchanged.
    retasked: bool,
    controller: SplitController,
    edge: EdgePipeline,
    device: DeviceModel,
    rr: RoundRobin<'a>,
    estimator: BandwidthEstimator,
    probe_noise: Rng,
    /// Context-role prompt rotation.
    ctx_prompts: Vec<String>,
    ctx_pi: usize,
    // ---- telemetry ----
    pub epochs: Vec<EpochRecord>,
    pub packets: Vec<PacketRecord>,
    acc_all: IouAccumulator,
    acc_orig: IouAccumulator,
    acc_ft: IouAccumulator,
    tier_secs: [f64; 3],
    total_energy: f64,
    infeasible: u64,
    delivered: u64,
    executed: u64,
    /// Served requests answered from the cloud response cache.
    cache_hits: u64,
    /// Cluster ring hops charged to this agent (spill + remote-hit trips).
    spill_hops: u64,
    /// Served requests answered from a sibling replica's cache.
    remote_hits: u64,
    /// Cells that answered this agent (one bit per cell, saturating at 64).
    cells_mask: u64,
    /// Virtual seconds of server-side work this agent induced (utilization).
    pub server_secs: f64,
    // ---- resilience telemetry (all 0 with retry/degrade off) ----
    captures: u64,
    retries: u64,
    shed_lost: u64,
    degraded: u64,
    abandoned: u64,
    degraded_secs: f64,
    retry_wait_secs: f64,
    ctx_correct: u64,
    ctx_total: u64,
    next_epoch_log: f64,
    retired: bool,
}

/// Server-side virtual seconds charged per Context response (the text-only
/// responder is far lighter than any Insight tail).
pub const CONTEXT_TAIL_SECS: f64 = 0.02;

/// Server-side virtual seconds charged when the serving layer answers a
/// request from its content-addressed response cache: one index lookup and
/// a reply — no tail execution at all (DESIGN.md "Cloud serving layer").
pub const CACHE_HIT_TAIL_SECS: f64 = 0.002;

/// Terminal resolution of one sampled serve attempt under the resilience
/// policy ([`UavAgent::serve_resilient`]).  `waited` is the virtual time
/// the agent spent backing off before resolving; it rides the agent's
/// clock so retries consume mission time.
enum Resolved {
    Served { served: Served, waited: f64 },
    Shed { waited: f64 },
    Degraded { waited: f64 },
    Abandoned { waited: f64 },
}

impl<'a> UavAgent<'a> {
    /// An Insight-stream agent (the paper's dynamic-mission loop).
    #[allow(clippy::too_many_arguments)]
    pub fn insight(
        id: usize,
        engine: &Engine,
        datasets: &[&'a Dataset],
        lut: &Lut,
        device: &DeviceModel,
        cfg: &MissionConfig,
        policy: Policy,
        intent: Intent,
        start_t: f64,
    ) -> Self {
        Self::new(id, UavRole::Insight, engine, datasets, lut, device, cfg, policy, intent, start_t)
    }

    /// A Context-stream agent cycling through awareness prompts.
    #[allow(clippy::too_many_arguments)]
    pub fn context(
        id: usize,
        engine: &Engine,
        datasets: &[&'a Dataset],
        lut: &Lut,
        device: &DeviceModel,
        cfg: &MissionConfig,
        prompts: &[&str],
        start_t: f64,
    ) -> Self {
        let intent = classify_intent(prompts.first().copied().unwrap_or("what is happening"));
        let mut agent = Self::new(
            id,
            UavRole::Context,
            engine,
            datasets,
            lut,
            device,
            cfg,
            Policy::Avery,
            intent,
            start_t,
        );
        agent.ctx_prompts = prompts.iter().map(|s| s.to_string()).collect();
        agent
    }

    #[allow(clippy::too_many_arguments)]
    fn new(
        id: usize,
        role: UavRole,
        engine: &Engine,
        datasets: &[&'a Dataset],
        lut: &Lut,
        device: &DeviceModel,
        cfg: &MissionConfig,
        policy: Policy,
        intent: Intent,
        start_t: f64,
    ) -> Self {
        let max_ctx = if cfg.max_context_pps > 0.0 {
            cfg.max_context_pps
        } else {
            1.0 / device.context_edge().latency_s
        };
        let mut controller = SplitController::new(lut.clone(), cfg.min_insight_pps, max_ctx);
        controller.hysteresis = cfg.hysteresis;
        controller.min_dwell_decisions = cfg.min_dwell;
        Self {
            id,
            role,
            launch_role: role,
            policy,
            start_t,
            t: start_t,
            cfg: cfg.clone(),
            intent,
            schedule: Vec::new(),
            sched_i: 0,
            intent_switches: 0,
            retasked: false,
            controller,
            edge: EdgePipeline::new(engine.clone(), device.clone(), lut.clone()),
            device: device.clone(),
            rr: RoundRobin::new(datasets.to_vec()),
            estimator: BandwidthEstimator::new(0.4),
            probe_noise: Rng::new(cfg.seed ^ 0x5EED),
            ctx_prompts: Vec::new(),
            ctx_pi: 0,
            epochs: Vec::new(),
            packets: Vec::new(),
            acc_all: IouAccumulator::default(),
            acc_orig: IouAccumulator::default(),
            acc_ft: IouAccumulator::default(),
            tier_secs: [0.0; 3],
            total_energy: 0.0,
            infeasible: 0,
            delivered: 0,
            executed: 0,
            cache_hits: 0,
            spill_hops: 0,
            remote_hits: 0,
            cells_mask: 0,
            server_secs: 0.0,
            captures: 0,
            retries: 0,
            shed_lost: 0,
            degraded: 0,
            abandoned: 0,
            degraded_secs: 0.0,
            retry_wait_secs: 0.0,
            ctx_correct: 0,
            ctx_total: 0,
            next_epoch_log: start_t,
            retired: false,
        }
    }

    /// The workload seed this agent runs with (telemetry/reproduction).
    pub fn seed(&self) -> u64 {
        self.cfg.seed
    }

    /// Install a timed intent schedule (absolute virtual times).  Entries
    /// are applied as the agent's clock passes them; see [`IntentSwitch`].
    pub fn set_intent_schedule(&mut self, mut schedule: Vec<IntentSwitch>) {
        schedule.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        self.schedule = schedule;
        self.sched_i = 0;
    }

    /// Apply every scheduled re-tasking due at the agent's current clock.
    fn apply_due_intents(&mut self) {
        while self.sched_i < self.schedule.len() && self.schedule[self.sched_i].t <= self.t {
            let prompt = self.schedule[self.sched_i].prompt.clone();
            self.sched_i += 1;
            let intent = classify_intent(&prompt);
            let new_role = match intent.level {
                IntentLevel::Context => UavRole::Context,
                IntentLevel::Insight => UavRole::Insight,
            };
            if new_role == UavRole::Context {
                // The scheduled prompt becomes the standing awareness query.
                self.ctx_prompts = vec![prompt];
                self.ctx_pi = 0;
            }
            self.intent_switches += 1;
            self.retasked = true;
            self.role = new_role;
            self.intent = intent;
        }
    }

    /// Prime the estimator with one ground-truth probe so the first decision
    /// is informed (the paper's controller boots from a calibration probe).
    pub fn prime(&mut self, uplink: &dyn Uplink) {
        self.estimator.observe(uplink.ground_truth(self.id, self.start_t));
    }

    /// Whether this agent still has cycles to run before `duration_secs`.
    pub fn active(&self, duration_secs: f64) -> bool {
        !self.retired && self.t < duration_secs
    }

    /// Advance one cycle.  Returns `false` once the agent has retired
    /// (dataset exhausted) — its clock no longer advances.
    pub fn step(&mut self, uplink: &mut dyn Uplink, server: &dyn ServePackets) -> Result<bool> {
        if self.retired {
            return Ok(false);
        }
        self.apply_due_intents();
        match self.role {
            UavRole::Insight => self.step_insight(uplink, server),
            UavRole::Context => self.step_context(uplink, server),
        }
    }

    /// Whether the resilience layer (retry budget / degradation) is armed.
    fn resilient(&self) -> bool {
        self.cfg.retry_budget > 0 || self.cfg.degrade
    }

    /// One sampled serve attempt under the resilience policy: retry
    /// retryable failures (sheds and injected faults) on exponential
    /// backoff in virtual time within the budget and deadline, then
    /// resolve terminally — served, shed, degraded, or abandoned.  Every
    /// attempt resolves to exactly one variant, which is what makes the
    /// request-conservation invariant hold by construction.  Flags off,
    /// this is a single `serve` call with errors propagated unchanged.
    fn serve_resilient(
        &mut self,
        server: &dyn ServePackets,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Resolved> {
        if !self.resilient() {
            return Ok(Resolved::Served { served: server.serve(pkt, prompt_ids, set)?, waited: 0.0 });
        }
        let mut waited = 0.0f64;
        let mut backoff = self.cfg.retry_backoff_secs.max(1e-6);
        let mut attempts = 0u32;
        let mut retry_pkt = None::<Packet>;
        loop {
            let attempt_pkt: &Packet = retry_pkt.as_ref().unwrap_or(pkt);
            match server.serve(attempt_pkt, prompt_ids, set) {
                Ok(served) => {
                    self.retry_wait_secs += waited;
                    return Ok(Resolved::Served { served, waited });
                }
                Err(e) => {
                    // Only typed, retryable serving failures enter the
                    // policy: sheds (overload) and injected faults
                    // (unreachability).  Closed is terminal by definition
                    // and execution errors are request-fatal — both
                    // resolve without burning retries.
                    let (retryable, shed) = match e.downcast_ref::<ServeError>() {
                        Some(ServeError::Shed { .. }) => (true, true),
                        Some(ServeError::Fault { .. }) => (true, false),
                        Some(ServeError::Closed) => (false, false),
                        Some(ServeError::Exec(_)) | None => return Err(e),
                    };
                    if retryable
                        && attempts < self.cfg.retry_budget
                        && waited + backoff <= self.cfg.retry_deadline_secs
                    {
                        attempts += 1;
                        self.retries += 1;
                        waited += backoff;
                        backoff *= 2.0;
                        // The retried request re-enters the cloud at the
                        // post-backoff virtual time, so fault windows and
                        // health re-probes see time advance while the
                        // agent backs off.
                        let mut p = pkt.clone();
                        p.t_capture = pkt.t_capture + waited;
                        retry_pkt = Some(p);
                        continue;
                    }
                    self.retry_wait_secs += waited;
                    if shed {
                        return Ok(Resolved::Shed { waited });
                    }
                    if self.cfg.degrade && pkt.kind == StreamKind::Insight {
                        return Ok(Resolved::Degraded { waited });
                    }
                    return Ok(Resolved::Abandoned { waited });
                }
            }
        }
    }

    fn step_insight(
        &mut self,
        uplink: &mut dyn Uplink,
        server: &dyn ServePackets,
    ) -> Result<bool> {
        let t = self.t;
        // ---- Sense: periodic probe + goodput feedback (EWMA). ----
        let true_bw = uplink.ground_truth(self.id, t);
        let probe = (true_bw * (1.0 + 0.02 * self.probe_noise.normal())).max(0.1);
        let est = self.estimator.observe(probe);

        // ---- Decide (Gate/Evaluate/Select or pinned static tier). ----
        let decision = match self.policy {
            Policy::Avery => {
                let state = RuntimeState {
                    bandwidth_mbps: est,
                    power_mode: "MODE_30W_ALL",
                    intent: self.intent.clone(),
                };
                match self.controller.select_configuration(&state, self.cfg.goal) {
                    Ok(ControllerDecision::Insight { tier, .. }) => Some(tier),
                    Ok(ControllerDecision::Context { .. }) => unreachable!("insight intent"),
                    Err(ControllerError::NoFeasibleInsightTier) => None,
                }
            }
            Policy::Static(tier) => Some(tier),
        };

        // Per-second epoch telemetry (Fig 9 a/b).
        while self.next_epoch_log <= t {
            self.epochs.push(EpochRecord {
                t: self.next_epoch_log,
                bandwidth_true_mbps: uplink.ground_truth(self.id, self.next_epoch_log),
                bandwidth_est_mbps: est,
                tier: decision,
                level: IntentLevel::Insight,
            });
            self.next_epoch_log += 1.0;
        }

        let Some(tier) = decision else {
            self.infeasible += 1;
            self.t += 1.0; // wait one epoch and re-sense
            return Ok(true);
        };

        // ---- Stream one Insight packet. ----
        let Some(item) = self.rr.next_item() else {
            self.retired = true;
            return Ok(false);
        };
        // Before any scheduled re-tasking, each dataset item's own prompt
        // drives the query (the paper's round-robin workload); after one,
        // the operator's standing intent is what the cloud serves and what
        // the mission scores against.
        let intent = if self.retasked {
            self.intent.clone()
        } else {
            classify_intent(item.prompt)
        };
        let class_id = intent.target_class.unwrap_or(item.class_id);
        let (pkt, cost) = self.edge.capture_insight(item.scene, self.cfg.split, tier, t)?;
        let tx = uplink.transmit(self.id, t, pkt.wire_bytes);
        self.estimator.observe(tx.goodput_mbps);
        let cycle = cost.latency_s.max(tx.tx_secs);
        // Micro-batched serving amortizes the per-request tail setup
        // (identical to the unbatched latency at batch_max <= 1); a cache
        // hit replaces tail execution with the lookup cost entirely.
        let mut tail = self.device.cloud_tail_latency_batched(self.cfg.split, self.cfg.batch_max);
        let tx_energy = self.device.tx_energy(tx.tx_secs);
        self.total_energy += cost.energy_j + tx_energy;
        self.tier_secs[tier.index()] += cycle;

        let mut iou = None;
        let mut waited = 0.0;
        if tx.delivered {
            self.delivered += 1;
            // Sample packets for real HLO execution with probability
            // 1/exec_every via the deterministic rng — a modulo would alias
            // against the strict generic/flood round-robin and starve one
            // corpus of accuracy samples.
            let sample = self.cfg.exec_every <= 1
                || self.probe_noise.below(self.cfg.exec_every) == 0;
            // Whether the cloud did the tail work (false once the request
            // resolved shed/degraded/abandoned — those charge no server
            // time).
            let mut server_side = true;
            if sample {
                self.captures += 1;
                match self.serve_resilient(
                    server,
                    &pkt,
                    &intent.token_ids,
                    item.corpus.weight_set(),
                )? {
                    Resolved::Served { served, waited: w } => {
                        waited = w;
                        if served.cache_hit {
                            self.cache_hits += 1;
                            tail = CACHE_HIT_TAIL_SECS;
                        }
                        // Cluster provenance: inter-cell hops (spill retries
                        // or a sibling-cache round trip) add their modeled
                        // latency to this request's tail.  Zero at
                        // --cells 1, so the default timing model is
                        // untouched.
                        if served.hops > 0 {
                            self.spill_hops += served.hops as u64;
                            if served.cache_hit {
                                self.remote_hits += 1;
                            }
                            tail += served.hop_secs;
                        }
                        self.cells_mask |= 1u64 << served.cell.min(63);
                        let logits =
                            served.resp.mask_logits.as_ref().expect("insight mask");
                        let s =
                            mask_iou(logits.as_f32()?, &item.scene.masks[class_id], 0.0);
                        let mut one = IouAccumulator::default();
                        one.push(s);
                        iou = Some(one.giou());
                        self.acc_all.push(s);
                        match item.corpus {
                            Corpus::Generic => self.acc_orig.push(s),
                            Corpus::Flood => self.acc_ft.push(s),
                        }
                        self.executed += 1;
                        // Per-request virtual latency for the tail-percentile
                        // telemetry: the full capture->deliver cycle (plus
                        // any retry backoff) and the final (cache-adjusted)
                        // cloud tail.
                        server.observe_latency(pkt.kind, cycle + waited + tail);
                    }
                    Resolved::Shed { waited: w } => {
                        waited = w;
                        tail = 0.0;
                        server_side = false;
                        self.shed_lost += 1;
                    }
                    Resolved::Degraded { waited: w } => {
                        // Graceful degradation: the cloud stayed unreachable
                        // past the retry budget, so the edge answers a
                        // Context-tier query locally (the paper's functional
                        // split as a fallback path) instead of losing the
                        // request.  No IoU sample — the degraded answer is a
                        // presence summary, not a mask.
                        waited = w;
                        let ctx = self.device.context_edge();
                        self.total_energy += ctx.energy_j;
                        tail = ctx.latency_s;
                        server_side = false;
                        self.degraded += 1;
                        self.degraded_secs += w + ctx.latency_s;
                    }
                    Resolved::Abandoned { waited: w } => {
                        waited = w;
                        tail = 0.0;
                        server_side = false;
                        self.abandoned += 1;
                    }
                }
            }
            if server_side {
                self.server_secs += tail;
            }
        }
        let t_deliver = t + cycle + waited + tail;
        self.packets.push(PacketRecord {
            t_send: t,
            t_deliver,
            tier,
            corpus: item.corpus,
            iou,
            edge_energy_j: cost.energy_j,
            tx_energy_j: tx_energy,
        });
        self.t += cycle + waited;
        Ok(true)
    }

    fn step_context(
        &mut self,
        uplink: &mut dyn Uplink,
        server: &dyn ServePackets,
    ) -> Result<bool> {
        let t = self.t;
        // Per-second epoch telemetry: Context epochs carry no tier — the
        // scenario timelines show exactly when a schedule parks the agent on
        // the lightweight stream (tier occupancy pauses).
        let est = self.estimator.estimate_mbps();
        while self.next_epoch_log <= t {
            self.epochs.push(EpochRecord {
                t: self.next_epoch_log,
                bandwidth_true_mbps: uplink.ground_truth(self.id, self.next_epoch_log),
                bandwidth_est_mbps: est,
                tier: None,
                level: IntentLevel::Context,
            });
            self.next_epoch_log += 1.0;
        }
        let Some(item) = self.rr.next_item() else {
            self.retired = true;
            return Ok(false);
        };
        let prompt = if self.ctx_prompts.is_empty() {
            "what is happening in this sector".to_string()
        } else {
            let p = self.ctx_prompts[self.ctx_pi % self.ctx_prompts.len()].clone();
            self.ctx_pi += 1;
            p
        };
        let intent = classify_intent(&prompt);
        debug_assert_eq!(intent.level, IntentLevel::Context);
        let (pkt, cost) = self.edge.capture_context(item.scene, t)?;
        // Context packets are lightweight but still occupy the shared
        // uplink: under fleet contention the stream can become
        // transmission-bound, which is exactly the regime the fleet
        // telemetry is meant to expose.
        let tx = uplink.transmit(self.id, t, pkt.wire_bytes);
        self.estimator.observe(tx.goodput_mbps);
        let cycle = cost.latency_s.max(tx.tx_secs);
        let tx_energy = self.device.tx_energy(tx.tx_secs);
        self.total_energy += cost.energy_j + tx_energy;
        let mut waited = 0.0;
        if tx.delivered {
            self.delivered += 1;
            let mut tail = CONTEXT_TAIL_SECS;
            let sample = self.cfg.exec_every <= 1
                || self.probe_noise.below(self.cfg.exec_every) == 0;
            let mut server_side = true;
            if sample {
                self.captures += 1;
                match self.serve_resilient(
                    server,
                    &pkt,
                    &intent.token_ids,
                    item.corpus.weight_set(),
                )? {
                    Resolved::Served { served, waited: w } => {
                        waited = w;
                        if served.cache_hit {
                            self.cache_hits += 1;
                            tail = CACHE_HIT_TAIL_SECS;
                        }
                        // Same cluster hop charging as the Insight stream.
                        if served.hops > 0 {
                            self.spill_hops += served.hops as u64;
                            if served.cache_hit {
                                self.remote_hits += 1;
                            }
                            tail += served.hop_secs;
                        }
                        self.cells_mask |= 1u64 << served.cell.min(63);
                        for (cls, &logit) in served.resp.presence.iter().enumerate() {
                            let gt = item.scene.masks[cls].iter().any(|&m| m > 0.5);
                            if (logit > 0.0) == gt {
                                self.ctx_correct += 1;
                            }
                            self.ctx_total += 1;
                        }
                        self.executed += 1;
                        server.observe_latency(pkt.kind, cycle + waited + tail);
                    }
                    Resolved::Shed { waited: w } => {
                        waited = w;
                        tail = 0.0;
                        server_side = false;
                        self.shed_lost += 1;
                    }
                    // Context requests never degrade (they already run the
                    // lightest query there is) — `serve_resilient` only
                    // degrades Insight packets — so an unreachable cloud
                    // abandons the query.
                    Resolved::Degraded { waited: w } | Resolved::Abandoned { waited: w } => {
                        waited = w;
                        tail = 0.0;
                        server_side = false;
                        self.abandoned += 1;
                    }
                }
            }
            if server_side {
                self.server_secs += tail;
            }
        }
        self.t += cycle + waited;
        Ok(true)
    }

    /// Presence-answer accuracy over executed Context queries (Context role).
    pub fn context_accuracy(&self) -> f64 {
        self.ctx_correct as f64 / self.ctx_total.max(1) as f64
    }

    /// Fold the agent's accumulators into a [`RunSummary`].  `duration_secs`
    /// is the fleet mission horizon; throughput is averaged over the agent's
    /// own active window `[start_t, duration_secs)`.
    pub fn finish(&self, duration_secs: f64) -> RunSummary {
        let window = (duration_secs - self.start_t).max(1e-9);
        let avg_pps = self.delivered as f64 / window;
        RunSummary {
            policy: match self.role {
                UavRole::Insight => self.policy.label(),
                UavRole::Context => "Context".to_string(),
            },
            delivered: self.delivered,
            executed: self.executed,
            insight_executed: self.acc_all.n() as u64,
            avg_pps,
            avg_iou: self.acc_all.avg_iou(),
            avg_iou_orig: self.acc_orig.avg_iou(),
            avg_iou_ft: self.acc_ft.avg_iou(),
            giou: self.acc_all.giou(),
            ciou: self.acc_all.ciou(),
            total_energy_j: self.total_energy,
            energy_per_packet_j: if self.delivered > 0 {
                self.total_energy / self.delivered as f64
            } else {
                0.0
            },
            tier_secs: self.tier_secs,
            switches: self.controller.switches,
            intent_switches: self.intent_switches,
            infeasible_epochs: self.infeasible,
            cache_hits: self.cache_hits,
            spill_hops: self.spill_hops,
            remote_hits: self.remote_hits,
            cells_mask: self.cells_mask,
            captures: self.captures,
            retries: self.retries,
            shed_lost: self.shed_lost,
            degraded: self.degraded,
            abandoned: self.abandoned,
            degraded_secs: self.degraded_secs,
            retry_wait_secs: self.retry_wait_secs,
        }
    }
}

/// Run the 20-minute (by default) Insight-stream mission (paper §5.3):
/// one [`UavAgent`] over a dedicated link.
pub fn run_insight_mission(
    engine: &Engine,
    datasets: &[&Dataset],
    lut: &Lut,
    device: &DeviceModel,
    link: &mut Link,
    cfg: &MissionConfig,
    policy: Policy,
) -> Result<InsightRun> {
    let mut agent = UavAgent::insight(
        0,
        engine,
        datasets,
        lut,
        device,
        cfg,
        policy,
        default_insight_intent(),
        0.0,
    );
    let server = CloudServer::new(engine.clone());
    agent.prime(link);
    while agent.active(cfg.duration_secs) {
        if !agent.step(link, &server)? {
            break;
        }
    }
    let summary = agent.finish(cfg.duration_secs);
    Ok(InsightRun { epochs: agent.epochs, packets: agent.packets, summary })
}

/// Result of a Context-stream mission (the §5.2.2 characterization + the
/// paper's triage workflow of §4.3).
#[derive(Clone, Debug, Default)]
pub struct ContextRun {
    pub updates: u64,
    pub achieved_pps: f64,
    /// Presence-answer accuracy against GT (both classes).
    pub presence_accuracy: f64,
    pub edge_latency_s: f64,
    pub insight_edge_latency_s: f64,
    /// On-device speedup of Context over the Insight head (paper: 6.4x).
    pub speedup: f64,
}

/// Run a Context-stream mission: stream context queries at the
/// compute-bound rate and score the text-level presence answers.
pub fn run_context_mission(
    engine: &Engine,
    datasets: &[&Dataset],
    lut: &Lut,
    device: &DeviceModel,
    duration_secs: f64,
    prompts: &[&str],
) -> Result<ContextRun> {
    let mut edge = EdgePipeline::new(engine.clone(), device.clone(), lut.clone());
    let server = CloudServer::new(engine.clone());
    let mut rr = RoundRobin::new(datasets.to_vec());
    let ctx_cost = device.context_edge();
    let rate = 1.0 / ctx_cost.latency_s;
    let mut t = 0.0;
    let mut updates = 0u64;
    let mut correct = 0u64;
    let mut total = 0u64;
    let mut pi = 0usize;
    while t < duration_secs {
        let Some(item) = rr.next_item() else { break };
        let prompt = prompts[pi % prompts.len()];
        pi += 1;
        let intent = classify_intent(prompt);
        debug_assert_eq!(intent.level, IntentLevel::Context);
        let (pkt, cost) = edge.capture_context(item.scene, t)?;
        let resp = server.process(&pkt, &intent.token_ids, item.corpus.weight_set())?;
        for (cls, &logit) in resp.presence.iter().enumerate() {
            let gt = item.scene.masks[cls].iter().any(|&m| m > 0.5);
            if (logit > 0.0) == gt {
                correct += 1;
            }
            total += 1;
        }
        updates += 1;
        t += cost.latency_s;
    }
    // The stream is compute-bound: the achieved rate can exceed `rate` only
    // through end-of-window rounding, so clamp once at construction.
    let achieved_pps = (updates as f64 / duration_secs.max(1e-9)).min(rate);
    Ok(ContextRun {
        updates,
        achieved_pps,
        presence_accuracy: correct as f64 / total.max(1) as f64,
        edge_latency_s: ctx_cost.latency_s,
        insight_edge_latency_s: device.insight_edge(1).latency_s,
        speedup: device.insight_edge(1).latency_s / ctx_cost.latency_s,
    })
}

/// Intent used by the Insight mission — exposed for tests.
pub fn default_insight_intent() -> Intent {
    classify_intent("highlight the stranded people")
}
