//! Megafleet event core: the sharded calendar-queue scheduler that pushes
//! the fleet loop from tens of agents to 16k+ (DESIGN.md "Megafleet
//! core").
//!
//! The unsharded loop in [`super::fleet`] steps one global min-clock heap
//! over a mutable [`crate::netsim::SharedLink`]; both are inherently
//! serial.  This module trades the *continuous* contention model for an
//! **epoch-quantized** one so the fleet partitions across worker threads:
//!
//! * Virtual time is divided into epochs of [`EPOCH_SECS`].  During epoch
//!   `k` every link query (probe, transfer integration, telemetry
//!   backfill) sees only occupancy windows **committed in epochs `< k`**
//!   (the [`FrozenIndex`]).  Windows created during epoch `k` buffer
//!   shard-locally and merge at the epoch barrier.
//! * With the link state frozen, agents are mutually independent inside an
//!   epoch: each shard owns a disjoint agent subset (round-robin by id) in
//!   dense arrays and steps them wheel-bucket by wheel-bucket with no
//!   locks, no channels and no per-event allocation.
//! * Every probabilistic draw — link jitter/loss, probe noise, fault
//!   injection — comes from a **per-agent** stream keyed on the global
//!   agent id and consumed in that agent's own request order, so the draw
//!   sequence is a pure function of the agent's trajectory, never of the
//!   shard partition.
//!
//! Together these make the output a pure function of `(config, seed)`:
//! `--shards T` is byte-identical to `--shards 1` for every T, which is
//! the correctness oracle CI's `scale-smoke` job `cmp`-gates.  The
//! epoch-quantized contention model is *not* byte-identical to the
//! unsharded path (it sees fleet load one epoch late); the flag-unset
//! legacy path is untouched and keeps its pinned outputs.

use std::cell::{Cell, RefCell};

use anyhow::{bail, Result};

use crate::cloud::{
    CloudCluster, ClusterConfig, ClusterStats, Served, ServeError, ServePackets,
};
use crate::coordinator::Lut;
use crate::dataset::Dataset;
use crate::energy::DeviceModel;
use crate::faults::{FaultCounts, FaultEvent, FaultInjector, FaultKind, FaultPlan};
use crate::netsim::{BandwidthTrace, LinkConfig, TxOutcome, Uplink};
use crate::packet::{Packet, StreamKind};
use crate::runtime::Engine;
use crate::telemetry::LatencyHistogram;
use crate::util::Rng;

use super::fleet::{build_agents, fold_fleet, FleetConfig, FleetRun};
use super::UavAgent;

/// Epoch length (virtual seconds): the synchronization quantum of the
/// sharded link exchange.  Small enough that contention feedback lags by
/// well under one agent cycle; large enough that barrier cost amortizes
/// over many agent steps.
pub const EPOCH_SECS: f64 = 0.5;

/// Sorted-bound index over every committed occupancy window `[from,
/// until)`.  The active count at `t` under the half-open predicate
/// `from <= t && until > t` (exactly `SharedLink::others_active`'s filter)
/// is `#(from <= t) - #(until <= t)`, answered with two binary searches —
/// O(log W) per query instead of the unsharded O(W) scan, which is what
/// keeps 16k concurrent transfer histories queryable.
#[derive(Clone, Debug, Default)]
pub struct FrozenIndex {
    starts: Vec<f64>,
    ends: Vec<f64>,
}

impl FrozenIndex {
    /// Committed windows covering `t`: `from <= t && until > t`.
    pub fn active_at(&self, t: f64) -> usize {
        let begun = self.starts.partition_point(|&s| s <= t);
        let drained = self.ends.partition_point(|&e| e <= t);
        begun.saturating_sub(drained)
    }

    /// Committed windows so far.
    pub fn len(&self) -> usize {
        self.starts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.starts.is_empty()
    }

    /// Commit one epoch's windows: sort the batch bounds and merge into
    /// the standing sorted arrays (linear in total size — no full resort).
    pub fn commit(&mut self, batch: &[(f64, f64)]) {
        if batch.is_empty() {
            return;
        }
        let mut s: Vec<f64> = batch.iter().map(|w| w.0).collect();
        let mut e: Vec<f64> = batch.iter().map(|w| w.1).collect();
        s.sort_unstable_by(f64::total_cmp);
        e.sort_unstable_by(f64::total_cmp);
        self.starts = merge_sorted(&self.starts, &s);
        self.ends = merge_sorted(&self.ends, &e);
    }
}

fn merge_sorted(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// One agent's own committed windows (small; subtracted from the global
/// count so an agent never contends with itself, mirroring the unsharded
/// link's `f.uav != uav` exclusion).
#[derive(Clone, Debug, Default)]
struct OwnWindows {
    starts: Vec<f64>,
    ends: Vec<f64>,
}

impl OwnWindows {
    fn active_at(&self, t: f64) -> usize {
        let begun = self.starts.partition_point(|&s| s <= t);
        let drained = self.ends.partition_point(|&e| e <= t);
        begun.saturating_sub(drained)
    }

    fn push(&mut self, from: f64, until: f64) {
        let i = self.starts.partition_point(|&s| s <= from);
        self.starts.insert(i, from);
        let j = self.ends.partition_point(|&e| e <= until);
        self.ends.insert(j, until);
    }
}

/// Per-shard mutable link state: the per-agent rng streams (full
/// fleet-sized so stream identity is a function of the global agent id,
/// not the shard), per-agent own-window indexes, and the epoch's pending
/// (uncommitted) windows.
struct ShardLinkState {
    cfg: LinkConfig,
    rngs: Vec<Rng>,
    own: Vec<OwnWindows>,
    /// Windows opened this epoch: `(uav, from, until)` — invisible to
    /// every query until the barrier commits them.
    pending: Vec<(usize, f64, f64)>,
}

impl ShardLinkState {
    fn new(cfg: &LinkConfig, n_uavs: usize) -> Self {
        // Identical stream derivation to `SharedLink::new`: stream i
        // belongs to global agent i whichever shard owns it.
        let rngs = (0..n_uavs)
            .map(|i| Rng::new(cfg.seed ^ (0xF1EE7 + i as u64).wrapping_mul(0x9E37)))
            .collect();
        Self {
            cfg: cfg.clone(),
            rngs,
            own: (0..n_uavs).map(|_| OwnWindows::default()).collect(),
            pending: Vec::new(),
        }
    }
}

/// The epoch-frozen [`Uplink`] view a shard steps its agents against:
/// reads come from the shared [`FrozenIndex`], writes buffer into the
/// shard-local pending list.  The transmit arithmetic mirrors
/// `SharedLink::transmit` / `transfer_secs` term for term — only the
/// occupancy-set *snapshot* differs (epoch-frozen instead of live).
struct ShardLink<'s> {
    trace: &'s BandwidthTrace,
    frozen: &'s FrozenIndex,
    st: &'s mut ShardLinkState,
}

impl ShardLink<'_> {
    fn others_active(&self, uav: usize, t: f64) -> usize {
        self.frozen
            .active_at(t)
            .saturating_sub(self.st.own[uav].active_at(t))
    }

    fn transfer_secs(&mut self, uav: usize, t: f64, wire_bytes: f64) -> f64 {
        let jitter = 1.0 + self.st.cfg.jitter_std * self.st.rngs[uav].normal();
        let mut bits = wire_bytes * 8.0 * jitter.max(0.5);
        let mut now = t;
        let mut secs = 0.0;
        for _ in 0..6000 {
            let n = 1 + self.others_active(uav, now);
            let bw_bps = self.trace.at(now) * 1e6 / n as f64;
            let step = self.trace.dt.min(1.0);
            let can = bw_bps * step;
            if bits <= can {
                secs += bits / bw_bps;
                return secs;
            }
            bits -= can;
            secs += step;
            now += step;
        }
        secs
    }
}

impl Uplink for ShardLink<'_> {
    fn ground_truth(&self, uav: usize, t: f64) -> f64 {
        let n = 1 + self.others_active(uav, t);
        self.trace.at(t) / n as f64
    }

    fn transmit(&mut self, uav: usize, t: f64, wire_bytes: f64) -> TxOutcome {
        let mut attempts = 1u32;
        let air_secs = self.transfer_secs(uav, t, wire_bytes);
        let mut total_secs = air_secs + self.st.cfg.extra_latency_s;
        let mut delivered = true;
        let loss = self.st.cfg.loss_prob;
        self.st.pending.push((uav, t, t + air_secs));
        if loss > 0.0 && self.st.rngs[uav].f64() < loss {
            attempts = 2;
            let retry_from = t + total_secs;
            let retry = self.transfer_secs(uav, retry_from, wire_bytes);
            if self.st.rngs[uav].f64() < loss {
                delivered = false;
            }
            self.st.pending.push((uav, retry_from, retry_from + retry));
            total_secs += retry + self.st.cfg.extra_latency_s;
        }
        let goodput = if total_secs > 0.0 {
            wire_bytes * 8.0 / 1e6 / total_secs
        } else {
            f64::INFINITY
        };
        TxOutcome { tx_secs: total_secs, goodput_mbps: goodput, delivered, attempts }
    }
}

/// Mix for deriving per-agent values from the base fault seed
/// (splitmix64 finalizer).
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Derive agent `uav`'s fault plan from the mission plan.  Window faults
/// (crash / stall / exec-error / wire-corrupt) apply to every agent — each
/// draws against them from its own seeded stream in its own request order.
/// A one-shot `SessionDrop` keeps the mission-level "one drop per event"
/// meaning by electing exactly one victim agent per event, chosen by a
/// seeded hash so the election is a pure function of `(plan seed, event
/// index, fleet size)` — never of the shard layout.
fn agent_plan(plan: &FaultPlan, uav: usize, n_uavs: usize) -> FaultPlan {
    let mut events = Vec::with_capacity(plan.events.len());
    let mut drop_i = 0u64;
    for ev in &plan.events {
        if matches!(ev, FaultEvent::SessionDrop { .. }) {
            let victim = (mix64(plan.seed ^ (0x5E55_10D0 + drop_i)) % n_uavs.max(1) as u64)
                as usize;
            drop_i += 1;
            if victim != uav {
                continue;
            }
        }
        events.push(ev.clone());
    }
    FaultPlan {
        events,
        // Per-agent derived draw stream keyed on the global agent id.
        seed: plan.seed ^ mix64(uav as u64 ^ 0xA6E1_7),
    }
}

/// Per-shard serving front: a shard-local [`CloudCluster`] (consistent-hash
/// routing and spill are per-request pure, so K cells behave identically
/// whichever shard's replica of the ring serves the request) plus the
/// sharded chaos layer — per-agent [`FaultInjector`]s in front of the
/// static ring.  Virtual latency lands in shard-local histograms and
/// merges commutatively at the end.
struct ShardServer {
    cluster: CloudCluster,
    /// Per-agent injectors indexed by global id (`Some` only for owned
    /// agents); `None` entirely when no fault plan is armed.
    injectors: Option<RefCell<Vec<Option<FaultInjector>>>>,
    /// Global id of the agent currently stepping — [`Packet`] carries no
    /// sender identity, so the scheduler pins it before each step.
    current_uav: Cell<usize>,
    vlat: [Cell<LatencyHistogram>; 2],
    /// Chaos-path spill-hop / cluster-shed counters (the wrapper bypasses
    /// the cluster's own ring walk when injectors are armed).
    served_at_hop: RefCell<Vec<u64>>,
    shed: Cell<u64>,
}

impl ShardServer {
    fn new(cluster: CloudCluster, injectors: Option<Vec<Option<FaultInjector>>>) -> Self {
        let cells = cluster.cells();
        Self {
            cluster,
            injectors: injectors.map(RefCell::new),
            current_uav: Cell::new(0),
            vlat: [Cell::new(LatencyHistogram::new()), Cell::new(LatencyHistogram::new())],
            served_at_hop: RefCell::new(vec![0u64; cells]),
            shed: Cell::new(0),
        }
    }

    /// The chaos-armed request path: `CloudCluster::try_process_chaos`'s
    /// injection ordering (session drop → wire corrupt → per-hop crash /
    /// exec-error / stall) against the *static* full ring.  The health
    /// machine (quarantine, re-probe, MTTR/TTD timeline) is a global
    /// sequential observer and does not shard — a crashed cell here is
    /// simply skipped while its window is open, so failover behavior is a
    /// pure function of virtual time and the per-agent draw streams.
    fn serve_chaos(
        &self,
        inj: &mut FaultInjector,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Served, ServeError> {
        let t = pkt.t_capture;
        if inj.take_session_drop(t) {
            return Err(ServeError::Fault { kind: FaultKind::SessionDrop });
        }
        if inj.draw_wire_corrupt(t) {
            return Err(ServeError::Fault { kind: FaultKind::WireCorrupt });
        }
        let cfg = self.cluster.config();
        let order = self.cluster.placement(pkt, set);
        let tries = order.len().min(cfg.spill_max as usize + 1);
        let mut last_fault: Option<FaultKind> = None;
        for (hop, &cell) in order.iter().take(tries).enumerate() {
            if inj.crash_active(cell, t) {
                inj.record(FaultKind::CellCrash);
                last_fault = Some(FaultKind::CellCrash);
                continue;
            }
            if inj.draw_exec_error(cell, t) {
                return Err(ServeError::Fault { kind: FaultKind::ExecError });
            }
            match self.cluster.cell(cell).try_process(pkt, prompt_ids, set) {
                Ok(served) => {
                    let stall = inj.stall_secs(cell, t);
                    {
                        let mut sah = self.served_at_hop.borrow_mut();
                        let slot = hop.min(sah.len().saturating_sub(1));
                        sah[slot] += 1;
                    }
                    return Ok(Served {
                        resp: served.resp,
                        cache_hit: served.cache_hit,
                        hops: hop as u32,
                        hop_secs: hop as f64 * cfg.hop_latency_secs + stall,
                        cell,
                    });
                }
                Err(ServeError::Shed { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        if let Some(kind) = last_fault {
            return Err(ServeError::Fault { kind });
        }
        self.shed.set(self.shed.get() + 1);
        Err(ServeError::Shed { hops: tries.saturating_sub(1) as u32 })
    }
}

impl ServePackets for ShardServer {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served> {
        match &self.injectors {
            None => self.cluster.process_sync(pkt, prompt_ids, set),
            Some(all) => {
                let uav = self.current_uav.get();
                let mut all = all.borrow_mut();
                let inj = all[uav]
                    .as_mut()
                    .expect("request from an agent this shard does not own");
                self.serve_chaos(inj, pkt, prompt_ids, set).map_err(anyhow::Error::from)
            }
        }
    }

    fn observe_latency(&self, kind: StreamKind, virtual_secs: f64) {
        let slot = &self.vlat[kind as usize];
        let mut h = slot.get();
        h.record(virtual_secs);
        slot.set(h);
    }

    fn latency_histograms(&self) -> Option<(LatencyHistogram, LatencyHistogram)> {
        Some((self.vlat[0].get(), self.vlat[1].get()))
    }
}

/// One scheduler shard: a dense arena of owned agents, the calendar-queue
/// wheel bucketing them by next-event epoch, the shard-local link state
/// and the shard-local serving front.
struct Shard<'a> {
    agents: Vec<UavAgent<'a>>,
    link: ShardLinkState,
    server: ShardServer,
    /// Wheel: `buckets[k]` holds local indices of agents whose next event
    /// falls in epoch `k`.  Indices recycle through the Vec storage — no
    /// per-event allocation once the wheel warms up.
    buckets: Vec<Vec<u32>>,
    /// Owned agents that have not yet retired or run out the clock.
    live: usize,
}

impl<'a> Shard<'a> {
    /// Step every agent due in `epoch` until it crosses `epoch_end` (or
    /// finishes), re-bucketing survivors at their next event epoch.
    fn run_epoch(
        &mut self,
        epoch: usize,
        epoch_end: f64,
        duration: f64,
        trace: &BandwidthTrace,
        frozen: &FrozenIndex,
    ) -> Result<()> {
        let slot = epoch.min(self.buckets.len() - 1);
        let due = std::mem::take(&mut self.buckets[slot]);
        let mut link = ShardLink { trace, frozen, st: &mut self.link };
        for li in due {
            let (still_active, next_t) = {
                let a = &mut self.agents[li as usize];
                self.server.current_uav.set(a.id);
                while a.active(duration) && a.t < epoch_end {
                    a.step(&mut link, &self.server)?;
                }
                (a.active(duration), a.t)
            };
            if still_active {
                let next = ((next_t / EPOCH_SECS).floor() as usize)
                    .max(epoch + 1)
                    .min(self.buckets.len() - 1);
                self.buckets[next].push(li);
            } else {
                self.live -= 1;
            }
        }
        Ok(())
    }

    /// Commit this epoch's pending windows into the per-agent own-window
    /// indexes and hand them to the coordinator's global batch.
    fn drain_pending(&mut self, batch: &mut Vec<(f64, f64)>) {
        for &(uav, from, until) in &self.link.pending {
            self.link.own[uav].push(from, until);
            batch.push((from, until));
        }
        self.link.pending.clear();
    }
}

/// Outcome of a sharded fleet mission: the standard [`FleetRun`] aggregate
/// plus the cross-shard-merged serving stats and (when a fault plan was
/// armed) the summed per-agent injection counters.
pub struct ShardedRun {
    pub run: FleetRun,
    pub cluster_stats: ClusterStats,
    /// Summed per-agent injector counters; `None` when no fault plan was
    /// armed.  The sharded chaos path has no cluster health machine, so
    /// there is no [`crate::cloud::ChaosStats`] here.
    pub injected: Option<FaultCounts>,
    /// Effective shard count (requested, capped at the fleet size).
    pub shards: usize,
}

/// Run a fleet mission on the sharded epoch-quantized core.  Output is a
/// pure function of `(cfg, cluster_cfg, seed)` — identical for every
/// `shards` value — which `rust/tests/scale.rs` and CI's `scale-smoke`
/// job gate.
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_mission_sharded(
    engine: &Engine,
    datasets: &[&Dataset],
    lut: &Lut,
    device: &DeviceModel,
    trace: &BandwidthTrace,
    link_cfg: &LinkConfig,
    cfg: &FleetConfig,
    cluster_cfg: &ClusterConfig,
    workers_per_cell: usize,
    shards: usize,
) -> Result<ShardedRun> {
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    // The response cache (and its replication) couples agents through
    // shared mutable serving state, which would make outcomes depend on
    // the shard partition — exactly what the determinism oracle forbids.
    if cluster_cfg.serving.cache_entries > 0 {
        bail!(
            "--shards is incompatible with the response cache (--cache-entries): \
             cached responses couple agents across shards and break shard-count \
             determinism"
        );
    }
    if cluster_cfg.replicas > 1 {
        bail!("--shards is incompatible with cache replication (--replicas > 1)");
    }

    let duration = cfg.mission.duration_secs;
    let n = cfg.n_uavs;
    let shards = shards.min(n.max(1));
    let n_buckets = (duration / EPOCH_SECS).ceil() as usize + 2;

    let chaos_plan = cluster_cfg.faults.clone();
    // Shard clusters never arm the cluster-level injector/health machine —
    // sharded chaos runs through the per-agent injectors instead.
    let mut shard_cluster_cfg = cluster_cfg.clone();
    shard_cluster_cfg.faults = None;

    // Round-robin ownership by global id: agent i -> shard i % T.
    let mut shard_vec: Vec<Shard> = Vec::with_capacity(shards);
    for _ in 0..shards {
        let cluster = CloudCluster::with_config(
            vec![engine.clone(); workers_per_cell.max(1)],
            shard_cluster_cfg.clone(),
        );
        shard_vec.push(Shard {
            agents: Vec::new(),
            link: ShardLinkState::new(link_cfg, n),
            server: ShardServer::new(cluster, None),
            buckets: vec![Vec::new(); n_buckets],
            live: 0,
        });
    }
    for (i, agent) in build_agents(engine, datasets, lut, device, cfg)
        .into_iter()
        .enumerate()
    {
        let sh = &mut shard_vec[i % shards];
        let bucket = ((agent.start_t / EPOCH_SECS).floor() as usize).min(n_buckets - 1);
        sh.buckets[bucket].push(sh.agents.len() as u32);
        sh.agents.push(agent);
        sh.live += 1;
    }
    if let Some(plan) = &chaos_plan {
        for sh in shard_vec.iter_mut() {
            let mut injectors: Vec<Option<FaultInjector>> = (0..n).map(|_| None).collect();
            for a in &sh.agents {
                injectors[a.id] = Some(FaultInjector::new(agent_plan(plan, a.id, n)));
            }
            sh.server.injectors = Some(RefCell::new(injectors));
        }
    }

    let mut frozen = FrozenIndex::default();

    // Prime every agent's estimator against the (empty) frozen state —
    // the same first observation the unsharded path makes against a
    // fresh link.
    for sh in shard_vec.iter_mut() {
        let link = ShardLink { trace, frozen: &frozen, st: &mut sh.link };
        for a in &mut sh.agents {
            a.prime(&link);
        }
    }

    // ---- Epoch loop: parallel shard advance, then a barrier commit. ----
    let mut epoch = 0usize;
    let mut batch: Vec<(f64, f64)> = Vec::new();
    while shard_vec.iter().any(|sh| sh.live > 0) && epoch < n_buckets {
        let epoch_end = (epoch + 1) as f64 * EPOCH_SECS;
        if shard_vec.len() == 1 {
            shard_vec[0].run_epoch(epoch, epoch_end, duration, trace, &frozen)?;
        } else {
            let frozen_ref = &frozen;
            let results: Vec<Result<()>> = std::thread::scope(|scope| {
                let handles: Vec<_> = shard_vec
                    .iter_mut()
                    .map(|sh| {
                        scope.spawn(move || {
                            sh.run_epoch(epoch, epoch_end, duration, trace, frozen_ref)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard thread panicked"))
                    .collect()
            });
            for r in results {
                r?;
            }
        }
        batch.clear();
        for sh in shard_vec.iter_mut() {
            sh.drain_pending(&mut batch);
        }
        frozen.commit(&batch);
        epoch += 1;
    }

    // ---- Merge: agents back in id order, stats commutatively. ----
    let mut agents: Vec<UavAgent> = Vec::with_capacity(n);
    let mut lat = (LatencyHistogram::new(), LatencyHistogram::new());
    let mut cluster_stats: Option<ClusterStats> = None;
    let mut injected: Option<FaultCounts> = chaos_plan.as_ref().map(|_| [0u64; 5]);
    for sh in shard_vec.into_iter() {
        let (c, i) = sh
            .server
            .latency_histograms()
            .expect("shard server always records latency");
        lat.0.merge(&c);
        lat.1.merge(&i);
        let mut stats = sh.server.cluster.stats();
        {
            let sah = sh.server.served_at_hop.borrow();
            for (acc, &v) in stats.served_at_hop.iter_mut().zip(sah.iter()) {
                *acc += v;
            }
        }
        stats.shed += sh.server.shed.get();
        if let (Some(totals), Some(injs)) = (injected.as_mut(), sh.server.injectors.as_ref())
        {
            for inj in injs.borrow().iter().flatten() {
                let c = inj.counts();
                for (t, v) in totals.iter_mut().zip(c.iter()) {
                    *t += v;
                }
            }
        }
        cluster_stats = Some(match cluster_stats.take() {
            None => stats,
            Some(mut acc) => {
                for (a, b) in acc.per_cell.iter_mut().zip(stats.per_cell.iter()) {
                    a.merge(b);
                }
                acc.total.merge(&stats.total);
                for (a, b) in acc.remote_hits.iter_mut().zip(stats.remote_hits.iter()) {
                    *a += b;
                }
                for (a, b) in acc.served_at_hop.iter_mut().zip(stats.served_at_hop.iter()) {
                    *a += b;
                }
                acc.shed += stats.shed;
                acc
            }
        });
        agents.extend(sh.agents);
    }
    agents.sort_by_key(|a| a.id);

    let mut cluster_stats = cluster_stats.expect("at least one shard");
    // Virtual latency is agent-facing and recorded at the shard servers;
    // surface the merged histograms where the unsharded cluster puts its
    // own (`CloudCluster::stats` fills `total.lat_*` from its vlat).
    cluster_stats.total.lat_context = lat.0;
    cluster_stats.total.lat_insight = lat.1;

    let run = fold_fleet(&agents, duration, cfg.workers, lat);
    Ok(ShardedRun { run, cluster_stats, injected, shards })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force reference for the frozen-index count: the exact
    /// half-open predicate `SharedLink::others_active` filters on.
    fn brute(wins: &[(f64, f64)], t: f64) -> usize {
        wins.iter().filter(|w| w.0 <= t && w.1 > t).count()
    }

    #[test]
    fn frozen_index_matches_brute_force_filter() {
        let mut rng = Rng::new(0xF00D);
        let mut idx = FrozenIndex::default();
        let mut all: Vec<(f64, f64)> = Vec::new();
        for _ in 0..40 {
            let batch: Vec<(f64, f64)> = (0..25)
                .map(|_| {
                    let from = rng.f64() * 100.0;
                    (from, from + rng.f64() * 8.0)
                })
                .collect();
            idx.commit(&batch);
            all.extend_from_slice(&batch);
            for _ in 0..50 {
                let t = rng.f64() * 110.0;
                assert_eq!(idx.active_at(t), brute(&all, t), "t={t}");
            }
        }
        // Boundary semantics: from inclusive, until exclusive.
        let mut idx = FrozenIndex::default();
        idx.commit(&[(1.0, 2.0)]);
        assert_eq!(idx.active_at(1.0), 1);
        assert_eq!(idx.active_at(2.0), 0);
        assert_eq!(idx.active_at(2.0 - 1e-9), 1);
        assert_eq!(idx.active_at(0.5), 0);
    }

    #[test]
    fn own_windows_subtract_exactly() {
        let mut own = OwnWindows::default();
        own.push(1.0, 3.0);
        own.push(2.0, 5.0);
        assert_eq!(own.active_at(2.5), 2);
        assert_eq!(own.active_at(4.0), 1);
        assert_eq!(own.active_at(5.0), 0);
    }

    #[test]
    fn merge_sorted_preserves_order() {
        let a = vec![1.0, 3.0, 5.0];
        let b = vec![0.5, 3.0, 9.0];
        let m = merge_sorted(&a, &b);
        assert_eq!(m, vec![0.5, 1.0, 3.0, 3.0, 5.0, 9.0]);
        assert_eq!(merge_sorted(&[], &b), b);
    }

    #[test]
    fn session_drop_elects_exactly_one_victim() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent::SessionDrop { at: 10.0 },
                FaultEvent::CellCrash { cell: 0, at: 20.0, recover_after: 5.0 },
            ],
            seed: 42,
        };
        let n = 16;
        let with_drop: Vec<usize> = (0..n)
            .filter(|&u| {
                agent_plan(&plan, u, n)
                    .events
                    .iter()
                    .any(|e| matches!(e, FaultEvent::SessionDrop { .. }))
            })
            .collect();
        assert_eq!(with_drop.len(), 1, "exactly one victim: {with_drop:?}");
        // Window faults reach every agent.
        for u in 0..n {
            assert!(agent_plan(&plan, u, n)
                .events
                .iter()
                .any(|e| matches!(e, FaultEvent::CellCrash { .. })));
        }
        // Per-agent seeds differ (independent draw streams).
        assert_ne!(agent_plan(&plan, 0, n).seed, agent_plan(&plan, 1, n).seed);
        // Victim election is stable across calls.
        assert_eq!(
            with_drop,
            (0..n)
                .filter(|&u| agent_plan(&plan, u, n)
                    .events
                    .iter()
                    .any(|e| matches!(e, FaultEvent::SessionDrop { .. })))
                .collect::<Vec<_>>()
        );
    }
}
