//! Fleet scheduler: N heterogeneous [`UavAgent`]s over one contended
//! [`SharedLink`], driven in global event order by a virtual clock
//! (DESIGN.md "Fleet subsystem").
//!
//! Each scheduling round steps the active agent with the smallest clock
//! (ties break to the lowest UAV id), so the interleaving of sense/decide/
//! stream cycles across the fleet is a pure function of the configuration —
//! same seed and same N always reproduce the same aggregate summary, which
//! the fleet determinism test pins down.
//!
//! Heterogeneity knobs: mixed Insight/Context roles (`context_every`),
//! staggered mission starts (`stagger_secs`), per-UAV workload seeds, and
//! alternating standing intents (people vs vehicles) across Insight UAVs.

use anyhow::Result;

use crate::cloud::ServePackets;
use crate::coordinator::{classify_intent, Lut};
use crate::dataset::Dataset;
use crate::energy::DeviceModel;
use crate::netsim::SharedLink;
use crate::runtime::Engine;
use crate::telemetry::LatencyHistogram;

use super::{EpochRecord, IntentSwitch, MissionConfig, Policy, RunSummary, UavAgent, UavRole};

/// Standing Insight intents rotated across the fleet (UAV 0 keeps the
/// single-UAV mission's default so an N=1 fleet reproduces `fig9`).
const INSIGHT_PROMPTS: [&str; 2] =
    ["highlight the stranded people", "mark the submerged vehicles"];

/// Awareness prompts cycled by Context-role UAVs — shared with the
/// single-UAV `avery streams` characterization so both score against the
/// same query distribution.
pub const CONTEXT_PROMPTS: [&str; 4] = [
    "what is happening in this sector",
    "are there any living beings on the rooftops",
    "are there any stranded vehicles here",
    "give me a quick status of this scene",
];

/// Fleet mission configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Fleet size N.
    pub n_uavs: usize,
    /// Per-UAV mission template; each agent gets `seed + id * 7919`.
    pub mission: MissionConfig,
    /// Every k-th UAV flies the Context stream (0 = all Insight).  An N=1
    /// fleet is always pure Insight regardless of this knob.
    pub context_every: usize,
    /// Launch separation between consecutive UAVs (virtual seconds).
    pub stagger_secs: f64,
    /// Cloud worker count (server-utilization denominator).
    pub workers: usize,
    /// Timed operator re-taskings applied to every UAV, expressed in
    /// mission-relative seconds and offset by each UAV's staggered start —
    /// the scenario library's intent schedules (see DESIGN.md).
    pub schedule: Vec<IntentSwitch>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            n_uavs: 4,
            mission: MissionConfig::default(),
            context_every: 4,
            stagger_secs: 5.0,
            workers: 2,
            schedule: Vec::new(),
        }
    }
}

/// One UAV's outcome within a fleet run.
#[derive(Clone, Debug)]
pub struct UavOutcome {
    pub id: usize,
    /// Launch role — intent schedules may have moved the agent between
    /// streams mid-mission (see `summary.intent_switches`).
    pub role: UavRole,
    pub start_t: f64,
    pub seed: u64,
    pub summary: RunSummary,
    /// Presence accuracy over executed Context queries (0 when the agent
    /// never flew the Context stream).
    pub context_accuracy: f64,
}

/// Aggregate result of a fleet mission.
#[derive(Clone, Debug)]
pub struct FleetRun {
    pub per_uav: Vec<UavOutcome>,
    /// Per-UAV epoch telemetry (uav id, record); Context epochs carry
    /// `tier: None` and `level: Context`.
    pub epochs: Vec<(usize, EpochRecord)>,
    /// Jain fairness index over Insight UAVs' delivered PPS.
    pub jain_pps: f64,
    /// Fleet-wide delivered packets per virtual second.
    pub aggregate_pps: f64,
    pub delivered_total: u64,
    pub executed_total: u64,
    pub switches_total: u64,
    /// Scheduled operator re-taskings applied across the fleet.
    pub intent_switches_total: u64,
    pub infeasible_total: u64,
    /// Served requests answered from the cloud response cache (0 unless the
    /// serving layer's cache is enabled).
    pub cache_hits_total: u64,
    /// Ring hops charged across the fleet by cluster spill / remote cache
    /// probes (0 on a single-cell cluster or bare pool).
    pub spill_hops_total: u64,
    /// Cache hits answered by a sibling cell's replica rather than the home
    /// cell (0 without cluster cache replication).
    pub remote_hits_total: u64,
    /// Distinct cluster cells that answered at least one request from any
    /// UAV (popcount of the OR of per-UAV `cells_mask`; 1 on a single pool).
    pub cells_hit: u32,
    /// Executed-weighted mean IoU over Insight UAVs.
    pub avg_iou: f64,
    /// Virtual server utilization: induced tail-seconds / (duration x workers).
    pub server_utilization: f64,
    pub total_energy_j: f64,
    /// Per-request virtual latency (capture->deliver cycle + cloud tail) over
    /// executed Context-class requests, recorded by the serving layer.  Empty
    /// when the server does not track latency (e.g. the bare `CloudServer`).
    pub lat_context: LatencyHistogram,
    /// Same, for Insight-class requests.
    pub lat_insight: LatencyHistogram,
    // ---- resilience totals (all 0 with the chaos layer disarmed) ----
    /// Sampled serve attempts entering the resilience layer: conservation
    /// denominator (`executed + shed_lost + degraded + abandoned`).
    pub captures_total: u64,
    /// Retry attempts issued fleet-wide.
    pub retries_total: u64,
    /// Requests lost to a terminal shed past the retry budget.
    pub shed_lost_total: u64,
    /// Insight requests that degraded to edge-local Context execution.
    pub degraded_total: u64,
    /// Requests abandoned with no answer at all.
    pub abandoned_total: u64,
    /// Virtual seconds spent in degraded handling fleet-wide.
    pub degraded_secs_total: f64,
    /// Virtual seconds spent backing off between retries fleet-wide.
    pub retry_wait_secs_total: f64,
}

/// Jain's fairness index: (Σx)² / (n · Σx²) — 1.0 when every UAV gets an
/// equal share, → 1/n under maximal starvation.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// Role of UAV `i` under a fleet configuration.
pub fn role_of(cfg: &FleetConfig, i: usize) -> UavRole {
    if cfg.n_uavs > 1 && cfg.context_every > 0 && i % cfg.context_every == cfg.context_every - 1
    {
        UavRole::Context
    } else {
        UavRole::Insight
    }
}

/// Per-UAV workload seed derivation — the single source of truth; telemetry
/// reads the seed back from the agent (`UavAgent::seed`).
fn uav_seed(cfg: &FleetConfig, i: usize) -> u64 {
    cfg.mission.seed.wrapping_add(i as u64 * 7919)
}

/// Build the heterogeneous agent fleet (shared with the sharded megafleet
/// core in [`super::shard`], so both paths launch byte-identical fleets).
pub(crate) fn build_agents<'a>(
    engine: &Engine,
    datasets: &[&'a Dataset],
    lut: &Lut,
    device: &DeviceModel,
    cfg: &FleetConfig,
) -> Vec<UavAgent<'a>> {
    // Clamp the launch stagger so the whole fleet is airborne within the
    // first half of the mission — otherwise a large N at a short duration
    // would leave late UAVs unlaunched, polluting fairness/throughput
    // aggregates with phantom zero-PPS agents.
    let stagger = cfg
        .stagger_secs
        .min(0.5 * cfg.mission.duration_secs / cfg.n_uavs.max(1) as f64);
    (0..cfg.n_uavs)
        .map(|i| {
            let mut mission = cfg.mission.clone();
            mission.seed = uav_seed(cfg, i);
            let start_t = i as f64 * stagger;
            let mut agent = match role_of(cfg, i) {
                UavRole::Context => UavAgent::context(
                    i, engine, datasets, lut, device, &mission, &CONTEXT_PROMPTS, start_t,
                ),
                UavRole::Insight => UavAgent::insight(
                    i,
                    engine,
                    datasets,
                    lut,
                    device,
                    &mission,
                    Policy::Avery,
                    classify_intent(INSIGHT_PROMPTS[i % INSIGHT_PROMPTS.len()]),
                    start_t,
                ),
            };
            if !cfg.schedule.is_empty() {
                // Mission-relative schedule, offset by this UAV's launch —
                // staggered fleets see the same re-tasking at the same point
                // of their own mission, not at the same wall instant.
                agent.set_intent_schedule(
                    cfg.schedule
                        .iter()
                        .map(|s| IntentSwitch { t: s.t + start_t, prompt: s.prompt.clone() })
                        .collect(),
                );
            }
            agent
        })
        .collect()
}

/// Run a fleet mission: event-ordered stepping of N agents over the shared
/// uplink, serving packets through `server` (the pool's in-process fast
/// path in the CLI driver).
pub fn run_fleet_mission(
    engine: &Engine,
    datasets: &[&Dataset],
    lut: &Lut,
    device: &DeviceModel,
    link: &mut SharedLink,
    cfg: &FleetConfig,
    server: &dyn ServePackets,
) -> Result<FleetRun> {
    let duration = cfg.mission.duration_secs;
    let mut agents = build_agents(engine, datasets, lut, device, cfg);
    for a in &mut agents {
        a.prime(link);
    }

    // ---- Global event loop: always step the earliest active agent. ----
    loop {
        let mut best: Option<usize> = None;
        for (i, a) in agents.iter().enumerate() {
            if a.active(duration) && best.map_or(true, |b| a.t < agents[b].t) {
                best = Some(i);
            }
        }
        let Some(i) = best else { break };
        agents[i].step(link, server)?;
    }

    let lat = server.latency_histograms().unwrap_or_default();
    Ok(fold_fleet(&agents, duration, cfg.workers, lat))
}

/// Fold per-UAV outcomes into the fleet aggregate — the single aggregation
/// path shared by the unsharded loop above and the sharded megafleet core
/// ([`super::shard`]), so both report identical totals for identical agent
/// trajectories.  `agents` must be in UAV-id order (the per-UAV series and
/// epoch telemetry are emitted in iteration order).
pub(crate) fn fold_fleet(
    agents: &[UavAgent],
    duration: f64,
    workers: usize,
    (lat_context, lat_insight): (LatencyHistogram, LatencyHistogram),
) -> FleetRun {
    let mut per_uav = Vec::with_capacity(agents.len());
    let mut epochs = Vec::new();
    let mut server_secs = 0.0f64;
    for a in agents {
        epochs.extend(a.epochs.iter().map(|&e| (a.id, e)));
        server_secs += a.server_secs;
        per_uav.push(UavOutcome {
            id: a.id,
            role: a.launch_role,
            start_t: a.start_t,
            seed: a.seed(),
            summary: a.finish(duration),
            context_accuracy: a.context_accuracy(),
        });
    }

    // Fairness is a launch-composition metric (Insight-launched UAVs'
    // delivered rates); quality and controller totals aggregate over every
    // agent — intent schedules can move any agent onto the Insight stream
    // mid-mission, and its IoU samples / tier switches must not vanish.
    let pps: Vec<f64> = per_uav
        .iter()
        .filter(|o| o.role == UavRole::Insight)
        .map(|o| o.summary.avg_pps)
        .collect();
    let delivered_total: u64 = per_uav.iter().map(|o| o.summary.delivered).sum();
    let insight_executed: u64 = per_uav.iter().map(|o| o.summary.insight_executed).sum();
    let avg_iou = if insight_executed > 0 {
        per_uav
            .iter()
            .map(|o| o.summary.avg_iou * o.summary.insight_executed as f64)
            .sum::<f64>()
            / insight_executed as f64
    } else {
        0.0
    };

    FleetRun {
        jain_pps: jain_index(&pps),
        aggregate_pps: delivered_total as f64 / duration.max(1e-9),
        delivered_total,
        executed_total: per_uav.iter().map(|o| o.summary.executed).sum(),
        switches_total: per_uav.iter().map(|o| o.summary.switches).sum(),
        intent_switches_total: per_uav.iter().map(|o| o.summary.intent_switches).sum(),
        infeasible_total: per_uav.iter().map(|o| o.summary.infeasible_epochs).sum(),
        cache_hits_total: per_uav.iter().map(|o| o.summary.cache_hits).sum(),
        spill_hops_total: per_uav.iter().map(|o| o.summary.spill_hops).sum(),
        remote_hits_total: per_uav.iter().map(|o| o.summary.remote_hits).sum(),
        cells_hit: per_uav
            .iter()
            .fold(0u64, |m, o| m | o.summary.cells_mask)
            .count_ones(),
        avg_iou,
        server_utilization: server_secs / (duration.max(1e-9) * workers.max(1) as f64),
        total_energy_j: per_uav.iter().map(|o| o.summary.total_energy_j).sum(),
        lat_context,
        lat_insight,
        captures_total: per_uav.iter().map(|o| o.summary.captures).sum(),
        retries_total: per_uav.iter().map(|o| o.summary.retries).sum(),
        shed_lost_total: per_uav.iter().map(|o| o.summary.shed_lost).sum(),
        degraded_total: per_uav.iter().map(|o| o.summary.degraded).sum(),
        abandoned_total: per_uav.iter().map(|o| o.summary.abandoned).sum(),
        degraded_secs_total: per_uav.iter().map(|o| o.summary.degraded_secs).sum(),
        retry_wait_secs_total: per_uav.iter().map(|o| o.summary.retry_wait_secs).sum(),
        per_uav,
        epochs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_index_bounds() {
        assert!((jain_index(&[1.0, 1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        // One UAV hogging everything: index -> 1/n.
        let j = jain_index(&[4.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12, "jain {j}");
        let mid = jain_index(&[2.0, 1.0, 1.0, 1.0]);
        assert!(mid > 0.25 && mid < 1.0);
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn role_assignment_mixes_streams() {
        let cfg = FleetConfig { n_uavs: 8, context_every: 4, ..FleetConfig::default() };
        let roles: Vec<UavRole> = (0..8).map(|i| role_of(&cfg, i)).collect();
        assert_eq!(roles.iter().filter(|r| **r == UavRole::Context).count(), 2);
        assert_eq!(roles[3], UavRole::Context);
        assert_eq!(roles[0], UavRole::Insight);
        // N=1 fleets are always pure Insight (fig9 parity).
        let solo = FleetConfig { n_uavs: 1, context_every: 1, ..FleetConfig::default() };
        assert_eq!(role_of(&solo, 0), UavRole::Insight);
    }
}
