# Build entrypoints documented in README.md / DESIGN.md.

.PHONY: artifacts build test bench bench-quick bench-all

# Train mini-LISA, profile the LUT, AOT-lower every path to artifacts/.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

# The perf-trajectory benches: the simulation kernel, the cloud serving
# layer, the multi-cell cluster and the chaos layer (write
# BENCH_simkernel.json / BENCH_serving.json / BENCH_cluster.json /
# BENCH_chaos.json — the machine-readable baselines CI's bench-smoke /
# serving-smoke / cluster-smoke / chaos-smoke jobs check) plus the L3
# hot-path microbenchmarks.  All run artifact-free.
bench:
	cargo bench --bench simkernel -- --out BENCH_simkernel.json
	cargo bench --bench serving -- --out BENCH_serving.json
	cargo bench --bench cluster -- --out BENCH_cluster.json
	cargo bench --bench chaos -- --out BENCH_chaos.json
	cargo bench --bench scenario_matrix -- --out BENCH_scenario_matrix.json
	cargo bench --bench hotpath

# CI-sized variant of the same set.
bench-quick:
	cargo bench --bench simkernel -- --quick --out BENCH_simkernel.json
	cargo bench --bench serving -- --quick --out BENCH_serving.json
	cargo bench --bench cluster -- --quick --out BENCH_cluster.json
	cargo bench --bench chaos -- --quick --out BENCH_chaos.json
	cargo bench --bench scenario_matrix -- --quick --out BENCH_scenario_matrix.json
	cargo bench --bench hotpath

# Every bench target, including the artifact-gated figure benches.
bench-all:
	cargo bench
