# Build entrypoints documented in README.md / DESIGN.md.

.PHONY: artifacts build test bench

# Train mini-LISA, profile the LUT, AOT-lower every path to artifacts/.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

bench:
	cargo bench
